//! Dense linear algebra (row-major, generic over [`Elem`]) — the native
//! tensor core (DESIGN.md §Native tensor core).
//!
//! Since the native backend became the artifact-free substrate for
//! training, eval, serve, and the un-gated test suite (PR 3), this IS a
//! hot path: every native matmul, transpose, and power-iteration matvec
//! lands here. Three disciplines keep it fast without giving up the
//! repo-wide bit-identity invariant:
//!
//! * **in-place ops** ([`Mat::matmul_into`], [`Mat::t_into`],
//!   [`Mat::matvec_into`], …) write into caller-owned storage so the
//!   step loop recycles buffers through an [`Arena`] instead of
//!   allocating per op;
//! * **row-parallel ops** ([`Mat::matmul_par`] and friends) fan
//!   contiguous output-row blocks across the persistent pool
//!   ([`crate::util::pool`]). Ownership is fixed by `(index, nthreads)`
//!   and every output element's k-accumulation order is exactly the
//!   serial loop's, so parallel results are **bit-identical** to serial
//!   at every thread count (docs/adr/005-parallel-tensor-core.md);
//! * **element genericity**: [`Mat<T>`] runs the same kernels over `f64`
//!   (the optimizer's domain, where the bit-identity proptests live) and
//!   `f32` (the forward/backward/decode compute path — state is f32 at
//!   rest, so the f32 path halves memory bandwidth). The kernels are one
//!   generic body, so the f32 path inherits the partition/accumulation
//!   contract verbatim: f32 results are bit-identical to *themselves*
//!   across thread counts, and agree with f64 within a proptested band
//!   (docs/adr/008-f32-compute-path.md).
//!
//! * **SIMD microkernels with runtime dispatch** ([`simd`]): the panel,
//!   matvec, transpose, and optimizer inner loops run through a kernel
//!   table resolved once from `REPRO_SIMD` + CPU detection (AVX2
//!   f64x4/f32x8 today, portable chunked fallback everywhere else).
//!   Lanes map to *distinct output elements*, every per-element
//!   k-accumulation keeps its ascending scalar order, and no FMA is
//!   emitted — so the vector path is bit-identical to the scalar path,
//!   and orthogonal to the thread-count contract above
//!   (docs/adr/010-simd-microkernels.md).
//!
//! NOTE the deliberate absence of zero-skip shortcuts: a `continue` on a
//! `0.0` operand would also skip `0.0 * NaN` and so hide a diverged
//! state's non-finite weights from the loss and the stability monitor's
//! detectors. IEEE propagation is load-bearing here; the
//! `nan_propagates_through_zero_operands` regression pins it.

pub mod lbfgs;
pub mod simd;

use crate::util::pool::{self, DisjointMut};

/// Element scalar for the tensor core: the closed set of arithmetic the
/// kernels and the native model need, implemented for `f64` and `f32`.
/// Everything is a thin inherent-method forward, so a `Mat<f64>`
/// monomorphization compiles to exactly the pre-generic code (same ops,
/// same order — the f64 bit-identity suite is the proof).
pub trait Elem:
    Copy
    + std::fmt::Debug
    + Default
    + PartialEq
    + PartialOrd
    + Send
    + Sync
    + 'static
    + std::ops::Add<Output = Self>
    + std::ops::Sub<Output = Self>
    + std::ops::Mul<Output = Self>
    + std::ops::Div<Output = Self>
    + std::ops::Neg<Output = Self>
    + std::ops::AddAssign
    + std::ops::SubAssign
    + std::ops::MulAssign
    + std::ops::DivAssign
{
    const ZERO: Self;
    const ONE: Self;
    const NEG_INF: Self;
    /// Tile edge for the blocked transpose / tiled matmul, sized so one
    /// row segment is 512 B (a few tiles fit in L1 alongside the output
    /// rows): 64 for f64, 128 for f32 — the f32 path used to inherit
    /// the f64 edge and run half-sized tiles. Per-element k order is
    /// blocking-independent, so the per-width edge moves no bits
    /// (`block_edge_is_per_elem_and_bit_free` pins it).
    const BLOCK: usize;
    fn from_f64(x: f64) -> Self;
    fn to_f64(self) -> f64;
    fn from_f32(x: f32) -> Self;
    fn to_f32(self) -> f32;
    fn sqrt(self) -> Self;
    fn exp(self) -> Self;
    fn ln(self) -> Self;
    fn sin(self) -> Self;
    fn cos(self) -> Self;
    fn powf(self, p: Self) -> Self;
    fn abs(self) -> Self;
    fn max(self, other: Self) -> Self;
    fn is_nan(self) -> bool;
    fn is_finite(self) -> bool;
    /// Bit pattern widened to u64 (f32 zero-extends) — the currency of
    /// the bits-equality tests, which must not depend on `T`.
    fn to_bits_u64(self) -> u64;

    // -- SIMD kernel hooks (forward to the width-matched entry of the
    //    runtime-dispatched table; see the [`simd`] module docs for the
    //    bit-identity argument) --

    /// `out[j] += a[k] * b[k * out.len() + j]`, k ascending per element
    /// — the register-tiled panel behind the matmul inner loop and
    /// `Wᵀy`.
    fn mul_add_panel(out: &mut [Self], a: &[Self], b: &[Self]);
    /// `out[i] = fold(0, acc + w[i*cols + k] * x[k])`, k ascending.
    fn matvec_fill(w: &[Self], cols: usize, x: &[Self], out: &mut [Self]);
    /// `dst[j*dcols + i] = src[i*scols + j]` over the given tile.
    #[allow(clippy::too_many_arguments)]
    fn transpose_tile(
        src: &[Self],
        scols: usize,
        dst: &mut [Self],
        dcols: usize,
        i0: usize,
        i1: usize,
        j0: usize,
        j1: usize,
    );
}

impl Elem for f64 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const NEG_INF: Self = f64::NEG_INFINITY;
    const BLOCK: usize = 64;
    fn mul_add_panel(out: &mut [Self], a: &[Self], b: &[Self]) {
        (simd::ops().mul_add_panel_f64)(out, a, b)
    }
    fn matvec_fill(w: &[Self], cols: usize, x: &[Self], out: &mut [Self]) {
        (simd::ops().matvec_f64)(w, cols, x, out)
    }
    fn transpose_tile(
        src: &[Self],
        scols: usize,
        dst: &mut [Self],
        dcols: usize,
        i0: usize,
        i1: usize,
        j0: usize,
        j1: usize,
    ) {
        (simd::ops().transpose_f64)(src, scols, dst, dcols, i0, i1, j0, j1)
    }
    fn from_f64(x: f64) -> Self {
        x
    }
    fn to_f64(self) -> f64 {
        self
    }
    fn from_f32(x: f32) -> Self {
        x as f64
    }
    fn to_f32(self) -> f32 {
        self as f32
    }
    fn sqrt(self) -> Self {
        f64::sqrt(self)
    }
    fn exp(self) -> Self {
        f64::exp(self)
    }
    fn ln(self) -> Self {
        f64::ln(self)
    }
    fn sin(self) -> Self {
        f64::sin(self)
    }
    fn cos(self) -> Self {
        f64::cos(self)
    }
    fn powf(self, p: Self) -> Self {
        f64::powf(self, p)
    }
    fn abs(self) -> Self {
        f64::abs(self)
    }
    fn max(self, other: Self) -> Self {
        f64::max(self, other)
    }
    fn is_nan(self) -> bool {
        f64::is_nan(self)
    }
    fn is_finite(self) -> bool {
        f64::is_finite(self)
    }
    fn to_bits_u64(self) -> u64 {
        self.to_bits()
    }
}

impl Elem for f32 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const NEG_INF: Self = f32::NEG_INFINITY;
    const BLOCK: usize = 128;
    fn mul_add_panel(out: &mut [Self], a: &[Self], b: &[Self]) {
        (simd::ops().mul_add_panel_f32)(out, a, b)
    }
    fn matvec_fill(w: &[Self], cols: usize, x: &[Self], out: &mut [Self]) {
        (simd::ops().matvec_f32)(w, cols, x, out)
    }
    fn transpose_tile(
        src: &[Self],
        scols: usize,
        dst: &mut [Self],
        dcols: usize,
        i0: usize,
        i1: usize,
        j0: usize,
        j1: usize,
    ) {
        (simd::ops().transpose_f32)(src, scols, dst, dcols, i0, i1, j0, j1)
    }
    fn from_f64(x: f64) -> Self {
        x as f32
    }
    fn to_f64(self) -> f64 {
        self as f64
    }
    fn from_f32(x: f32) -> Self {
        x
    }
    fn to_f32(self) -> f32 {
        self
    }
    fn sqrt(self) -> Self {
        f32::sqrt(self)
    }
    fn exp(self) -> Self {
        f32::exp(self)
    }
    fn ln(self) -> Self {
        f32::ln(self)
    }
    fn sin(self) -> Self {
        f32::sin(self)
    }
    fn cos(self) -> Self {
        f32::cos(self)
    }
    fn powf(self, p: Self) -> Self {
        f32::powf(self, p)
    }
    fn abs(self) -> Self {
        f32::abs(self)
    }
    fn max(self, other: Self) -> Self {
        f32::max(self, other)
    }
    fn is_nan(self) -> bool {
        f32::is_nan(self)
    }
    fn is_finite(self) -> bool {
        f32::is_finite(self)
    }
    fn to_bits_u64(self) -> u64 {
        self.to_bits() as u64
    }
}

/// Row-major dense matrix. The default element keeps the pre-generic
/// spelling alive: plain `Mat` *is* `Mat<f64>`, so the optimizer and the
/// bit-identity proptests read unchanged while the forward path
/// instantiates `Mat<f32>`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Mat<T = f64> {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<T>,
}

impl<T: Elem> Mat<T> {
    pub fn zeros(rows: usize, cols: usize) -> Mat<T> {
        Mat { rows, cols, data: vec![T::ZERO; rows * cols] }
    }

    pub fn from_rows(rows: Vec<Vec<T>>) -> Mat<T> {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        assert!(rows.iter().all(|x| x.len() == c), "ragged rows");
        Mat { rows: r, cols: c, data: rows.into_iter().flatten().collect() }
    }

    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Mat<T> {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data: data.iter().map(|&x| T::from_f32(x)).collect() }
    }

    pub fn randn(rows: usize, cols: usize, rng: &mut crate::util::rng::Pcg64) -> Mat<T> {
        let data = (0..rows * cols).map(|_| T::from_f64(rng.normal())).collect();
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> T {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut T {
        &mut self.data[i * self.cols + j]
    }

    /// Reshape to `(rows, cols)` zeros, reusing the existing allocation:
    /// the in-place ops' way of "allocating" their output. For
    /// accumulating consumers (matmul) the zero-fill is load-bearing.
    pub fn reset(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.resize(rows * cols, T::ZERO);
    }

    /// Reshape for consumers that overwrite EVERY element before any
    /// read (`t_into`, the head-view extraction): skips the zero-fill
    /// when the buffer already has the right length, halving store
    /// traffic on those ops. Callers must write the full extent — stale
    /// values are exposed otherwise.
    pub fn reset_for_overwrite(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        let len = rows * cols;
        if self.data.len() != len {
            self.data.clear();
            self.data.resize(len, T::ZERO);
        }
    }

    /// Blocked transpose: walks `T::BLOCK`-square tiles so reads and
    /// writes both stay within a cache-resident window on the larger test
    /// shapes (the naive column-strided write thrashes once a row of the
    /// output exceeds L1). Pure permutation — bit-identical to the naive
    /// loop at any tile edge and in any vector width.
    pub fn t(&self) -> Mat<T> {
        let mut out = Self::zeros(self.cols, self.rows);
        self.t_write(&mut out);
        out
    }

    /// [`Mat::t`] into a reused buffer (`t_write` assigns every element,
    /// so the reshape skips zero-filling).
    pub fn t_into(&self, out: &mut Mat<T>) {
        out.reset_for_overwrite(self.cols, self.rows);
        self.t_write(out);
    }

    fn t_write(&self, out: &mut Mat<T>) {
        for i0 in (0..self.rows).step_by(T::BLOCK) {
            let i1 = (i0 + T::BLOCK).min(self.rows);
            for j0 in (0..self.cols).step_by(T::BLOCK) {
                let j1 = (j0 + T::BLOCK).min(self.cols);
                T::transpose_tile(
                    &self.data, self.cols, &mut out.data, self.rows, i0, i1, j0, j1,
                );
            }
        }
    }

    /// Tiled ikj matmul over output rows `[i_lo, i_hi)`, accumulating
    /// into `out_rows` (that row range's storage, zero-initialized by the
    /// caller). The `(i, k)` loops are blocked so the touched rows of
    /// `other` and `out` stay cache-resident while a tile is consumed.
    /// For each output element the k-accumulation runs in ascending k
    /// order (tiles ascend, k ascends within a tile) — independent of
    /// `i_lo`/`i_hi` — so the sums, and the Newton-Schulz mirrors built
    /// on them, are bit-identical to the untiled serial loop no matter
    /// how the row range is partitioned.
    ///
    /// No zero-skip on `a`: `0.0 * NaN` must stay NaN (module docs).
    /// The `(k-block × row)` inner update is one [`Elem::mul_add_panel`]
    /// call — the SIMD dispatch point; its scalar table entry is this
    /// loop's historical `for k { for j { out[j] += a*b } }` body.
    fn matmul_rows(&self, other: &Mat<T>, out_rows: &mut [T], i_lo: usize, i_hi: usize) {
        let nc = other.cols;
        debug_assert_eq!(out_rows.len(), (i_hi - i_lo) * nc);
        for i0 in (i_lo..i_hi).step_by(T::BLOCK) {
            let i1 = (i0 + T::BLOCK).min(i_hi);
            for k0 in (0..self.cols).step_by(T::BLOCK) {
                let k1 = (k0 + T::BLOCK).min(self.cols);
                let b_panel = &other.data[k0 * nc..k1 * nc];
                for i in i0..i1 {
                    let a_col = &self.data[i * self.cols + k0..i * self.cols + k1];
                    let out_row = &mut out_rows[(i - i_lo) * nc..(i - i_lo + 1) * nc];
                    T::mul_add_panel(out_row, a_col, b_panel);
                }
            }
        }
    }

    /// Serial tiled matmul (see `matmul_rows` above for the order
    /// guarantees). Prefer [`Mat::matmul_into`] / [`Mat::matmul_par_into`]
    /// on hot paths.
    pub fn matmul(&self, other: &Mat<T>) -> Mat<T> {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Self::zeros(self.rows, other.cols);
        self.matmul_rows(other, &mut out.data, 0, self.rows);
        out
    }

    /// [`Mat::matmul`] into a reused buffer — bit-identical output.
    pub fn matmul_into(&self, other: &Mat<T>, out: &mut Mat<T>) {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        out.reset(self.rows, other.cols);
        self.matmul_rows(other, &mut out.data, 0, self.rows);
    }

    /// Row-parallel matmul: output rows are split into `threads`
    /// contiguous blocks (`pool::chunk_bounds` — ownership fixed by
    /// `(index, nthreads)`) and fanned across the persistent pool. Each
    /// block runs the serial tiled loop over its own rows, so the result
    /// is bit-identical to [`Mat::matmul`] at every thread count
    /// (DESIGN.md §Native tensor core).
    pub fn matmul_par(&self, other: &Mat<T>, threads: usize) -> Mat<T> {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Self::zeros(self.rows, other.cols);
        self.matmul_par_write(other, threads, &mut out);
        out
    }

    /// [`Mat::matmul_par`] into a reused buffer.
    pub fn matmul_par_into(&self, other: &Mat<T>, threads: usize, out: &mut Mat<T>) {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        out.reset(self.rows, other.cols);
        self.matmul_par_write(other, threads, out);
    }

    fn matmul_par_write(&self, other: &Mat<T>, threads: usize, out: &mut Mat<T>) {
        let nc = other.cols;
        let slots = DisjointMut::new(&mut out.data);
        pool::chunked_for(threads, self.rows, &|lo, hi| {
            // disjoint by chunked_for's contiguous row partition
            let out_rows = unsafe { slots.range_mut(lo * nc, (hi - lo) * nc) };
            self.matmul_rows(other, out_rows, lo, hi);
        });
    }

    pub fn matvec(&self, x: &[T]) -> Vec<T> {
        let mut out = Vec::with_capacity(self.rows);
        self.matvec_into(x, &mut out);
        out
    }

    /// `out = W x` into a reused buffer (resized to `rows`). Each output
    /// element is the same ascending-k left fold `sum::<f64>()` lowered
    /// to — bits did not move when this went generic, nor when the
    /// dispatch landed: SIMD lanes hold distinct output *rows*, never a
    /// split of one row's reduction.
    pub fn matvec_into(&self, x: &[T], out: &mut Vec<T>) {
        assert_eq!(self.cols, x.len());
        out.clear();
        out.resize(self.rows, T::ZERO);
        T::matvec_fill(&self.data, self.cols, x, out);
    }

    pub fn matvec_t(&self, y: &[T]) -> Vec<T> {
        let mut out = vec![T::ZERO; self.cols];
        self.matvec_t_write(y, &mut out);
        out
    }

    /// `out = Wᵀ y` into a reused buffer (resized to `cols`). Row
    /// accumulation ascends in `i` exactly as the allocating version —
    /// and no `y[i] == 0.0` skip: a NaN row must poison the output
    /// (module docs).
    pub fn matvec_t_into(&self, y: &[T], out: &mut Vec<T>) {
        out.clear();
        out.resize(self.cols, T::ZERO);
        self.matvec_t_write(y, out);
    }

    fn matvec_t_write(&self, y: &[T], out: &mut [T]) {
        assert_eq!(self.rows, y.len());
        assert_eq!(self.cols, out.len());
        // Wᵀy IS the panel kernel with a = y and the whole weight matrix
        // as the row panel: out[j] += y[i] * w[i][j], i ascending per
        // output element — exactly the historical loop's order.
        T::mul_add_panel(out, y, &self.data);
    }

    pub fn sub(&self, other: &Mat<T>) -> Mat<T> {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| *a - *b).collect(),
        }
    }

    pub fn scale(&self, s: T) -> Mat<T> {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|a| *a * s).collect(),
        }
    }

    /// `self *= s` in place — same per-element arithmetic as
    /// [`Mat::scale`], no allocation.
    pub fn scale_assign(&mut self, s: T) {
        for v in self.data.iter_mut() {
            *v *= s;
        }
    }

    /// `self += other` elementwise, in place.
    pub fn add_assign(&mut self, other: &Mat<T>) {
        debug_assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (o, v) in self.data.iter_mut().zip(&other.data) {
            *o += *v;
        }
    }

    /// Become a copy of `src`, reusing this matrix's allocation.
    pub fn copy_from(&mut self, src: &Mat<T>) {
        self.rows = src.rows;
        self.cols = src.cols;
        self.data.clear();
        self.data.extend_from_slice(&src.data);
    }

    pub fn fro(&self) -> T {
        self.data.iter().fold(T::ZERO, |acc, x| acc + *x * *x).sqrt()
    }
}

/// Buffer pool for the step loop's intermediate matrices: `take`/`put`
/// recycling turns the native forward/backward's per-op allocations into
/// steady-state reuse (capacity ratchets up to the high-water set of
/// live buffers and stays there). The free list is bucketed by capacity
/// with best-fit checkout, so a tiny request can never capture (and
/// orphan) the multi-MB logits buffer and force a regrow. Checked-out
/// values are plain [`Mat`]/`Vec<T>` — dropping one instead of
/// returning it is merely a lost reuse, never a leak or an error.
///
/// **Bounded**: mixed-shape churn (decode sessions of many lengths
/// cycling through one arena) used to grow the free list without limit —
/// every novel capacity left a buffer behind. Retained (free) bytes are
/// now capped at [`Arena::with_limit`] (default 256 MiB); on `put`, the
/// *smallest* free buffers are evicted first until the cap holds, so the
/// expensive multi-MB buffers stay recycled and only cheap-to-rebuild
/// small ones are dropped. Checked-out buffers never count against the
/// cap — it bounds idle footprint, not working set.
pub struct Arena<T = f64> {
    free: std::collections::BTreeMap<usize, Vec<Vec<T>>>,
    /// sum of `capacity * size_of::<T>()` over every free buffer
    retained_bytes: usize,
    limit_bytes: usize,
}

/// Default idle-footprint cap: generous next to the largest per-step
/// buffer (vocab-sized logits at f64 ≈ tens of MB) so steady-state
/// training/serving never evicts, while runaway mixed-shape churn is
/// bounded.
const ARENA_DEFAULT_LIMIT_BYTES: usize = 256 << 20;

impl<T> Default for Arena<T> {
    fn default() -> Self {
        Arena {
            free: std::collections::BTreeMap::new(),
            retained_bytes: 0,
            limit_bytes: ARENA_DEFAULT_LIMIT_BYTES,
        }
    }
}

impl<T: Elem> Arena<T> {
    /// An arena whose *free* (idle) footprint is capped at `limit_bytes`.
    pub fn with_limit(limit_bytes: usize) -> Arena<T> {
        Arena { limit_bytes, ..Arena::default() }
    }

    /// Bytes currently retained on the free list (checked-out buffers
    /// excluded). The mixed-shape churn tests assert this holds steady.
    pub fn retained_bytes(&self) -> usize {
        self.retained_bytes
    }

    pub fn limit_bytes(&self) -> usize {
        self.limit_bytes
    }

    /// Best-fit checkout: the smallest recycled capacity already holding
    /// `len`, else the largest available (regrows once and re-buckets at
    /// put), else a fresh empty vector.
    fn pop_fit(&mut self, len: usize) -> Vec<T> {
        let key = self
            .free
            .range(len..)
            .next()
            .map(|(k, _)| *k)
            .or_else(|| self.free.keys().next_back().copied());
        match key {
            Some(k) => {
                let bucket = self.free.get_mut(&k).expect("keyed bucket");
                let v = bucket.pop().expect("non-empty bucket");
                if bucket.is_empty() {
                    self.free.remove(&k);
                }
                self.retained_bytes -= v.capacity() * std::mem::size_of::<T>();
                v
            }
            None => Vec::new(),
        }
    }

    fn put_raw(&mut self, v: Vec<T>) {
        if v.capacity() == 0 {
            return; // nothing to recycle; don't grow the zero bucket
        }
        self.retained_bytes += v.capacity() * std::mem::size_of::<T>();
        self.free.entry(v.capacity()).or_default().push(v);
        // Evict smallest-first until the idle cap holds: large buffers
        // are the expensive ones to reallocate, so they are kept.
        while self.retained_bytes > self.limit_bytes {
            let k = *self.free.keys().next().expect("over-limit arena has buffers");
            let bucket = self.free.get_mut(&k).expect("keyed bucket");
            let dropped = bucket.pop().expect("non-empty bucket");
            if bucket.is_empty() {
                self.free.remove(&k);
            }
            self.retained_bytes -= dropped.capacity() * std::mem::size_of::<T>();
        }
    }

    /// A zeroed vector of length `len`, recycled when possible.
    pub fn vec(&mut self, len: usize) -> Vec<T> {
        let mut v = self.pop_fit(len);
        v.clear();
        v.resize(len, T::ZERO);
        v
    }

    /// A vector holding a copy of `src` (no intermediate zero-fill).
    pub fn vec_from(&mut self, src: &[T]) -> Vec<T> {
        let mut v = self.pop_fit(src.len());
        v.clear();
        v.extend_from_slice(src);
        v
    }

    pub fn put_vec(&mut self, v: Vec<T>) {
        self.put_raw(v);
    }

    /// A zeroed `(rows, cols)` matrix, recycled when possible.
    pub fn mat(&mut self, rows: usize, cols: usize) -> Mat<T> {
        Mat { rows, cols, data: self.vec(rows * cols) }
    }

    /// A recycled copy of `src`.
    pub fn mat_from(&mut self, src: &Mat<T>) -> Mat<T> {
        Mat { rows: src.rows, cols: src.cols, data: self.vec_from(&src.data) }
    }

    pub fn put(&mut self, m: Mat<T>) {
        self.put_raw(m.data);
    }
}

pub fn norm(x: &[f64]) -> f64 {
    x.iter().map(|v| v * v).sum::<f64>().sqrt()
}

pub fn normalize(x: &mut [f64]) -> f64 {
    let n = norm(x);
    if n > 0.0 {
        for v in x.iter_mut() {
            *v /= n;
        }
    }
    n
}

/// Reused iteration vectors for [`spectral_norm_op_into`]: the telemetry
/// path calls it every logged step, so the two power-iteration vectors
/// live here instead of being reallocated per call (mirrors the
/// persisted-u `PowerScratch` discipline of the optimizer path).
#[derive(Default)]
pub struct SpecScratch {
    v: Vec<f64>,
    u: Vec<f64>,
}

/// Spectral norm via power iteration on an implicit operator
/// (matvec, matvec_t) : R^n -> R^m, writing through caller scratch. The
/// closures fill a reused output buffer instead of returning a fresh
/// `Vec`, so a telemetry step allocates nothing. Arithmetic (including
/// the normalize order) is exactly [`spectral_norm_op`]'s — the
/// bits-equality test pins the two together.
pub fn spectral_norm_op_into(
    mut matvec: impl FnMut(&[f64], &mut Vec<f64>),
    mut matvec_t: impl FnMut(&[f64], &mut Vec<f64>),
    n: usize,
    iters: usize,
    rng: &mut crate::util::rng::Pcg64,
    s: &mut SpecScratch,
) -> f64 {
    s.v.clear();
    s.v.extend((0..n).map(|_| rng.normal()));
    normalize(&mut s.v);
    let mut sigma = 0.0;
    for _ in 0..iters {
        matvec(&s.v, &mut s.u);
        normalize(&mut s.u);
        matvec_t(&s.u, &mut s.v);
        sigma = normalize(&mut s.v);
    }
    sigma
}

/// Spectral norm via power iteration on an implicit operator
/// (matvec, matvec_t) : R^n -> R^m — mirrors the in-graph telemetry so the
/// Rust tests can cross-check HLO-computed values. Allocating convenience
/// wrapper over [`spectral_norm_op_into`].
pub fn spectral_norm_op(
    matvec: impl Fn(&[f64]) -> Vec<f64>,
    matvec_t: impl Fn(&[f64]) -> Vec<f64>,
    n: usize,
    iters: usize,
    rng: &mut crate::util::rng::Pcg64,
) -> f64 {
    let mut s = SpecScratch::default();
    spectral_norm_op_into(
        |x, out| {
            out.clear();
            out.extend_from_slice(&matvec(x));
        },
        |y, out| {
            out.clear();
            out.extend_from_slice(&matvec_t(y));
        },
        n,
        iters,
        rng,
        &mut s,
    )
}

pub fn spectral_norm(m: &Mat, iters: usize, rng: &mut crate::util::rng::Pcg64) -> f64 {
    spectral_norm_op(|x| m.matvec(x), |y| m.matvec_t(y), m.cols, iters, rng)
}

/// Newton-Schulz orthogonalization — host mirror of the L1 kernel, same
/// coefficients (Jordan et al. 2024). Used only in tests to cross-validate
/// numerics between layers.
pub const NS_COEFFS: (f64, f64, f64) = (3.4445, -4.7750, 2.0315);

pub fn newton_schulz(g: &Mat, steps: usize) -> Mat {
    let (a, b, c) = NS_COEFFS;
    let transposed = g.rows < g.cols;
    let mut x = if transposed { g.t() } else { g.clone() };
    let f = x.fro() + 1e-7;
    x = x.scale(1.0 / f);
    for _ in 0..steps {
        let gram = x.t().matmul(&x);
        let gram2 = gram.matmul(&gram);
        let mut bmat = gram.scale(b);
        for (o, g2) in bmat.data.iter_mut().zip(&gram2.data) {
            *o += c * g2;
        }
        let xb = x.matmul(&bmat);
        x = x.scale(a);
        for (o, v) in x.data.iter_mut().zip(&xb.data) {
            *o += v;
        }
    }
    if transposed {
        x.t()
    } else {
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn matmul_identity() {
        let mut rng = Pcg64::new(0);
        let a = Mat::randn(5, 7, &mut rng);
        let mut eye = Mat::zeros(7, 7);
        for i in 0..7 {
            *eye.at_mut(i, i) = 1.0;
        }
        assert_eq!(a.matmul(&eye).data, a.data);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Pcg64::new(1);
        let a = Mat::randn(6, 4, &mut rng);
        let x: Vec<f64> = (0..4).map(|_| rng.normal()).collect();
        let xm = Mat { rows: 4, cols: 1, data: x.clone() };
        let want = a.matmul(&xm).data;
        assert_eq!(a.matvec(&x), want);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::new(2);
        let a = Mat::randn(3, 8, &mut rng);
        assert_eq!(a.t().t(), a);
    }

    #[test]
    fn spectral_norm_of_diagonal() {
        let mut m = Mat::zeros(4, 4);
        for (i, s) in [3.0, 7.0, 1.0, 5.0].iter().enumerate() {
            *m.at_mut(i, i) = *s;
        }
        let mut rng = Pcg64::new(3);
        let s = spectral_norm(&m, 50, &mut rng);
        assert!((s - 7.0).abs() < 1e-6, "{s}");
    }

    #[test]
    fn spectral_norm_rank1_product_op() {
        // ||a bᵀ||_2 = |a||b|, computed through the implicit factored op
        let a = vec![1.0, 2.0, 2.0]; // |a| = 3
        let b = vec![3.0, 4.0]; // |b| = 5
        let mv = |x: &[f64]| -> Vec<f64> {
            let s: f64 = b.iter().zip(x).map(|(p, q)| p * q).sum();
            a.iter().map(|ai| ai * s).collect()
        };
        let mt = |y: &[f64]| -> Vec<f64> {
            let s: f64 = a.iter().zip(y).map(|(p, q)| p * q).sum();
            b.iter().map(|bi| bi * s).collect()
        };
        let mut rng = Pcg64::new(4);
        let s = spectral_norm_op(mv, mt, 2, 30, &mut rng);
        assert!((s - 15.0).abs() < 1e-9, "{s}");
    }

    /// The scratch-routed telemetry power iteration must be bit-identical
    /// to the allocating wrapper — the telemetry stream is diffed across
    /// runs, so the allocation fix must not move a single bit.
    #[test]
    fn spectral_norm_op_into_bit_matches_allocating() {
        let mut rng = Pcg64::new(40);
        let w: Mat = Mat::randn(9, 6, &mut rng);
        let mut rng_a = Pcg64::new(41);
        let want = spectral_norm_op(|x| w.matvec(x), |y| w.matvec_t(y), w.cols, 12, &mut rng_a);
        let mut rng_b = Pcg64::new(41);
        let mut scratch = SpecScratch::default();
        // dirty scratch from an unrelated earlier shape: must not leak in
        scratch.v = vec![99.0; 17];
        scratch.u = vec![-3.0; 2];
        let got = spectral_norm_op_into(
            |x, out| w.matvec_into(x, out),
            |y, out| w.matvec_t_into(y, out),
            w.cols,
            12,
            &mut rng_b,
            &mut scratch,
        );
        assert_eq!(want.to_bits(), got.to_bits(), "{want} vs {got}");
    }

    #[test]
    fn newton_schulz_orthogonalizes() {
        let mut rng = Pcg64::new(5);
        let g = Mat::randn(32, 8, &mut rng);
        let o = newton_schulz(&g, 5);
        // OᵀO ≈ I within the Jordan-coefficient band: the quintic pushes
        // singular values into roughly [0.7, 1.2] after 5 iterations, so
        // diagonal entries (σ²) live in ~[0.49, 1.45] and off-diagonals
        // stay small relative to the diagonal.
        let gram = o.t().matmul(&o);
        for i in 0..8 {
            let d = gram.at(i, i);
            assert!((0.4..1.5).contains(&d), "gram[{i}][{i}] = {d}");
            for j in 0..8 {
                if i != j {
                    assert!(gram.at(i, j).abs() < 0.35, "gram[{i}][{j}] = {}", gram.at(i, j));
                }
            }
        }
        let mut rng2 = Pcg64::new(6);
        let s = spectral_norm(&o, 40, &mut rng2);
        assert!(s < 1.35 && s > 0.6, "{s}");
    }

    /// Naive references for the tiled kernels: the blocked versions must
    /// be bit-identical (same per-element accumulation order), not just
    /// close — the Newton-Schulz cross-layer mirrors rely on it.
    fn t_naive(m: &Mat) -> Mat {
        let mut out = Mat::zeros(m.cols, m.rows);
        for i in 0..m.rows {
            for j in 0..m.cols {
                *out.at_mut(j, i) = m.at(i, j);
            }
        }
        out
    }

    fn matmul_naive(a: &Mat, b: &Mat) -> Mat {
        let mut out = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for k in 0..a.cols {
                let v = a.at(i, k);
                for j in 0..b.cols {
                    out.data[i * b.cols + j] += v * b.data[k * b.cols + j];
                }
            }
        }
        out
    }

    fn assert_bits_eq(want: &Mat, got: &Mat, what: &str) {
        assert_eq!((want.rows, want.cols), (got.rows, got.cols), "{what}: shape");
        for (i, (x, y)) in want.data.iter().zip(&got.data).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: drifted at flat index {i}");
        }
    }

    #[test]
    fn tiled_kernels_bit_match_naive_across_block_edges() {
        let mut rng = Pcg64::new(42);
        // shapes below, at, and straddling the 64-wide tile edge
        for (m, k, n) in [(3, 5, 4), (64, 64, 64), (70, 130, 65), (1, 200, 1), (129, 64, 63)] {
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let t_want = t_naive(&a);
            assert_bits_eq(&t_want, &a.t(), &format!("t() {m}x{k}"));
            let mut t_got = Mat::zeros(1, 1);
            a.t_into(&mut t_got);
            assert_bits_eq(&t_want, &t_got, &format!("t_into {m}x{k}"));
            let mm_want = matmul_naive(&a, &b);
            assert_bits_eq(&mm_want, &a.matmul(&b), &format!("matmul {m}x{k}x{n}"));
        }
    }

    /// The tentpole invariant: the parallel and in-place matmuls are
    /// bit-identical to the serial allocating one at every thread count,
    /// for shapes straddling the tile edge — including reused (dirty)
    /// output buffers.
    #[test]
    fn parallel_and_in_place_matmul_bit_match_serial() {
        let mut rng = Pcg64::new(43);
        let mut reused = Mat::zeros(3, 3);
        reused.data.fill(7.5); // dirty buffer: reset must erase history
        for (m, k, n) in [(1, 1, 1), (3, 5, 4), (64, 64, 64), (70, 130, 65), (129, 64, 63)] {
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let want = a.matmul(&b);
            a.matmul_into(&b, &mut reused);
            assert_bits_eq(&want, &reused, &format!("matmul_into {m}x{k}x{n}"));
            for threads in [1usize, 2, 3, 8] {
                let got = a.matmul_par(&b, threads);
                assert_bits_eq(&want, &got, &format!("matmul_par t={threads} {m}x{k}x{n}"));
                a.matmul_par_into(&b, threads, &mut reused);
                assert_bits_eq(
                    &want,
                    &reused,
                    &format!("matmul_par_into t={threads} {m}x{k}x{n}"),
                );
            }
        }
    }

    /// The f32 instantiation inherits the same partition/accumulation
    /// contract: bit-identical to its own serial loop at every thread
    /// count (docs/adr/008), and within float tolerance of the f64 path
    /// on the same values.
    #[test]
    fn f32_kernels_bit_match_serial_and_track_f64() {
        let mut rng = Pcg64::new(45);
        for (m, k, n) in [(1, 1, 1), (3, 5, 4), (64, 64, 64), (70, 130, 65)] {
            let a64: Mat<f64> = Mat::randn(m, k, &mut rng);
            let b64: Mat<f64> = Mat::randn(k, n, &mut rng);
            let a32: Mat<f32> = Mat::from_f32(
                m,
                k,
                &a64.data.iter().map(|&x| x as f32).collect::<Vec<_>>(),
            );
            let b32: Mat<f32> = Mat::from_f32(
                k,
                n,
                &b64.data.iter().map(|&x| x as f32).collect::<Vec<_>>(),
            );
            let want32 = a32.matmul(&b32);
            let mut reused: Mat<f32> = Mat::zeros(2, 2);
            reused.data.fill(7.5f32);
            for threads in [1usize, 2, 3, 8] {
                let got = a32.matmul_par(&b32, threads);
                for (x, y) in want32.data.iter().zip(&got.data) {
                    assert_eq!(x.to_bits(), y.to_bits(), "f32 par t={threads} {m}x{k}x{n}");
                }
                a32.matmul_par_into(&b32, threads, &mut reused);
                for (x, y) in want32.data.iter().zip(&reused.data) {
                    assert_eq!(x.to_bits(), y.to_bits(), "f32 par_into t={threads}");
                }
            }
            // transpose is a pure permutation in both widths
            let t32 = a32.t();
            for i in 0..m {
                for j in 0..k {
                    assert_eq!(t32.at(j, i).to_bits(), a32.at(i, j).to_bits());
                }
            }
            // f32 tracks f64 within a k-scaled relative band
            let want64 = a64.matmul(&b64);
            let tol = 1e-5 * (k as f64) + 1e-6;
            for (x64, x32) in want64.data.iter().zip(&want32.data) {
                let diff = (x64 - *x32 as f64).abs();
                assert!(diff <= tol * (1.0 + x64.abs()), "{x64} vs {x32} (tol {tol})");
            }
        }
    }

    /// Satellite regression for the per-`Elem` tile edge: f32 must get
    /// the larger edge (same 512 B row segment as f64's 64), and since
    /// per-element k order is blocking-independent, the f32 kernels must
    /// stay bit-identical to naive loops at shapes below / at /
    /// straddling the NEW 128 edge — if `BLOCK` ever collapses back to a
    /// shared constant or the edge moves bits, this trips.
    #[test]
    fn block_edge_is_per_elem_and_bit_free() {
        assert_eq!(<f64 as Elem>::BLOCK, 64);
        assert_eq!(<f32 as Elem>::BLOCK, 128);
        assert_eq!(
            <f64 as Elem>::BLOCK * std::mem::size_of::<f64>(),
            <f32 as Elem>::BLOCK * std::mem::size_of::<f32>(),
            "row segments should stay cache-size matched across widths"
        );
        let mut rng = Pcg64::new(46);
        for (m, k, n) in [(5usize, 127usize, 3usize), (128, 128, 64), (129, 130, 131)] {
            let a64: Mat<f64> = Mat::randn(m, k, &mut rng);
            let b64: Mat<f64> = Mat::randn(k, n, &mut rng);
            let a: Mat<f32> = Mat::from_f32(
                m,
                k,
                &a64.data.iter().map(|&x| x as f32).collect::<Vec<_>>(),
            );
            let b: Mat<f32> = Mat::from_f32(
                k,
                n,
                &b64.data.iter().map(|&x| x as f32).collect::<Vec<_>>(),
            );
            // naive f32 references (ascending-k, untiled)
            let mut mm = Mat::<f32>::zeros(m, n);
            for i in 0..m {
                for kk in 0..k {
                    let v = a.at(i, kk);
                    for j in 0..n {
                        mm.data[i * n + j] += v * b.data[kk * n + j];
                    }
                }
            }
            let got = a.matmul(&b);
            for (x, y) in mm.data.iter().zip(&got.data) {
                assert_eq!(x.to_bits(), y.to_bits(), "f32 matmul {m}x{k}x{n}");
            }
            let t = a.t();
            for i in 0..m {
                for j in 0..k {
                    assert_eq!(t.at(j, i).to_bits(), a.at(i, j).to_bits());
                }
            }
        }
    }

    /// Regression for the removed zero-skip: a NaN in one operand must
    /// reach the output even when the matching element of the other
    /// operand is exactly 0.0 (the old `if a == 0.0 {{ continue }}`
    /// suppressed IEEE propagation and could hide a diverged state).
    #[test]
    fn nan_propagates_through_zero_operands() {
        // A's first row is all zeros; B's first row holds a NaN — every
        // element of out's first row goes through 0.0 * finite + 0.0 *
        // NaN and must be NaN
        let a = Mat::from_rows(vec![vec![0.0, 0.0], vec![1.0, 2.0]]);
        let b = Mat::from_rows(vec![vec![f64::NAN, 1.0], vec![3.0, 4.0]]);
        let out = a.matmul(&b);
        assert!(out.at(0, 0).is_nan(), "zero row must not mask NaN");
        assert!(out.at(1, 0).is_nan());
        assert_eq!(out.at(0, 1), 0.0, "finite column stays finite");
        for threads in [2usize, 4] {
            let par = a.matmul_par(&b, threads);
            assert!(par.at(0, 0).is_nan(), "parallel path must propagate too");
        }
        // matvec_t: zero dual vector element against a NaN row
        let w = Mat::from_rows(vec![vec![f64::NAN, 1.0], vec![2.0, 3.0]]);
        let out = w.matvec_t(&[0.0, 1.0]);
        assert!(out[0].is_nan(), "matvec_t zero-skip would mask the NaN row");
        // and the f32 instantiation must not regress it either
        let a32: Mat<f32> = Mat::from_rows(vec![vec![0.0f32, 0.0], vec![1.0, 2.0]]);
        let b32: Mat<f32> = Mat::from_rows(vec![vec![f32::NAN, 1.0], vec![3.0, 4.0]]);
        assert!(a32.matmul(&b32).at(0, 0).is_nan(), "f32 path must propagate NaN");
    }

    #[test]
    fn matvec_into_and_matvec_t_into_match_allocating() {
        let mut rng = Pcg64::new(44);
        let w = Mat::randn(67, 130, &mut rng);
        let x: Vec<f64> = (0..130).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..67).map(|_| rng.normal()).collect();
        let mut buf = vec![5.0; 3]; // dirty + wrong size
        w.matvec_into(&x, &mut buf);
        for (a, b) in w.matvec(&x).iter().zip(&buf) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        w.matvec_t_into(&y, &mut buf);
        for (a, b) in w.matvec_t(&y).iter().zip(&buf) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn arena_recycles_and_zeroes() {
        let mut ar = Arena::default();
        let mut m = ar.mat(4, 5);
        m.data.fill(9.0);
        let cap_before = m.data.capacity();
        ar.put(m);
        let m2 = ar.mat(2, 3); // smaller: same buffer (best fit), zeroed
        assert!(m2.data.iter().all(|&v| v == 0.0));
        assert_eq!(m2.data.capacity(), cap_before);
        let src = Mat::from_rows(vec![vec![1.0, 2.0]]);
        ar.put(m2);
        let c = ar.mat_from(&src);
        assert_eq!(c.data, vec![1.0, 2.0]);
        assert_eq!((c.rows, c.cols), (1, 2));
    }

    /// Best-fit checkout: a small request must not capture a much larger
    /// recycled buffer when a right-sized one is available.
    #[test]
    fn arena_best_fit_prefers_smallest_sufficient_buffer() {
        let mut ar = Arena::default();
        let big = ar.vec(1 << 16);
        let big_cap = big.capacity();
        let small = ar.vec(8);
        let small_cap = small.capacity();
        assert!(small_cap < big_cap);
        ar.put_vec(big);
        ar.put_vec(small);
        let tiny = ar.vec(4);
        assert!(tiny.capacity() <= small_cap, "tiny take grabbed the big buffer");
        let big2 = ar.vec(1 << 16);
        assert_eq!(big2.capacity(), big_cap, "big buffer must still be available");
    }

    /// The unbounded-growth bugfix: mixed-shape churn (every put a novel
    /// capacity, the decode-session pattern) must hold retained bytes at
    /// or under the configured cap, evicting smallest-first so the
    /// largest buffer survives.
    #[test]
    fn arena_eviction_bounds_mixed_shape_churn() {
        let limit = 4096 * std::mem::size_of::<f64>();
        let mut ar: Arena<f64> = Arena::with_limit(limit);
        assert_eq!(ar.limit_bytes(), limit);
        // 200 distinct capacities cycling through: unbounded before the cap
        for i in 0..200usize {
            let v: Vec<f64> = Vec::with_capacity(17 + 13 * i);
            ar.put_vec(v);
            assert!(
                ar.retained_bytes() <= limit,
                "iteration {i}: retained {} > limit {}",
                ar.retained_bytes(),
                limit
            );
        }
        // the largest resident buffer survived eviction (smallest-first)
        let biggest = ar.vec(1);
        assert!(
            biggest.capacity() * std::mem::size_of::<f64>() > limit / 2,
            "eviction dropped the expensive large buffer (cap {})",
            biggest.capacity()
        );
        // accounting: checkout decremented what the checkout removed
        assert!(ar.retained_bytes() <= limit);
        // zero-capacity puts are dropped, not bucketed
        ar.put_vec(Vec::new());
        let before = ar.retained_bytes();
        ar.put_vec(Vec::new());
        assert_eq!(ar.retained_bytes(), before);
    }

    #[test]
    fn arena_f32_recycles_independently() {
        let mut ar: Arena<f32> = Arena::default();
        let mut v = ar.vec(16);
        v.fill(3.0);
        let cap = v.capacity();
        ar.put_vec(v);
        assert_eq!(ar.retained_bytes(), cap * std::mem::size_of::<f32>());
        let v2 = ar.vec(10);
        assert_eq!(v2.capacity(), cap);
        assert!(v2.iter().all(|&x| x == 0.0));
        assert_eq!(ar.retained_bytes(), 0);
    }

    #[test]
    fn newton_schulz_wide_matches_tall() {
        let mut rng = Pcg64::new(7);
        let g = Mat::randn(8, 32, &mut rng);
        let o_wide = newton_schulz(&g, 5);
        let o_tall = newton_schulz(&g.t(), 5).t();
        for (a, b) in o_wide.data.iter().zip(&o_tall.data) {
            assert!((a - b).abs() < 1e-12);
        }
    }
}
