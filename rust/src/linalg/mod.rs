//! Host-side dense linear algebra (f64, row-major).
//!
//! Used by the scaling-law fits, the coordinator's host-side cross-checks
//! of the in-graph spectral telemetry, and the test suite. This is NOT the
//! hot path — model math runs inside the AOT-compiled XLA programs.

pub mod lbfgs;

/// Tile edge for the blocked transpose / tiled matmul: 64 f64 = 512 B per
/// row segment, a few tiles fit in L1 alongside the output rows.
const BLOCK: usize = 64;

#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_rows(rows: Vec<Vec<f64>>) -> Mat {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        assert!(rows.iter().all(|x| x.len() == c), "ragged rows");
        Mat { rows: r, cols: c, data: rows.into_iter().flatten().collect() }
    }

    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data: data.iter().map(|&x| x as f64).collect() }
    }

    pub fn randn(rows: usize, cols: usize, rng: &mut crate::util::rng::Pcg64) -> Mat {
        let data = (0..rows * cols).map(|_| rng.normal()).collect();
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }

    /// Blocked transpose: walks `BLOCK x BLOCK` tiles so reads and writes
    /// both stay within a cache-resident window on the larger test shapes
    /// (the naive column-strided write thrashes once a row of the output
    /// exceeds L1). Pure permutation — bit-identical to the naive loop.
    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i0 in (0..self.rows).step_by(BLOCK) {
            let i1 = (i0 + BLOCK).min(self.rows);
            for j0 in (0..self.cols).step_by(BLOCK) {
                let j1 = (j0 + BLOCK).min(self.cols);
                for i in i0..i1 {
                    for j in j0..j1 {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        out
    }

    /// Tiled ikj matmul: the `(i, k)` loops are blocked so the touched
    /// rows of `other` and `out` stay cache-resident while a tile is
    /// consumed. For each output element the k-accumulation still runs in
    /// ascending k order (tiles ascend, k ascends within a tile), so the
    /// f32/f64 sums — and the Newton-Schulz mirrors built on them — are
    /// bit-identical to the untiled loop.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let nc = other.cols;
        let mut out = Mat::zeros(self.rows, nc);
        for i0 in (0..self.rows).step_by(BLOCK) {
            let i1 = (i0 + BLOCK).min(self.rows);
            for k0 in (0..self.cols).step_by(BLOCK) {
                let k1 = (k0 + BLOCK).min(self.cols);
                for i in i0..i1 {
                    let arow = &self.data[i * self.cols..(i + 1) * self.cols];
                    let out_row = &mut out.data[i * nc..(i + 1) * nc];
                    for k in k0..k1 {
                        let a = arow[k];
                        if a == 0.0 {
                            continue;
                        }
                        let orow = &other.data[k * nc..(k + 1) * nc];
                        for (o, &b) in out_row.iter_mut().zip(orow) {
                            *o += a * b;
                        }
                    }
                }
            }
        }
        out
    }

    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        (0..self.rows)
            .map(|i| {
                self.data[i * self.cols..(i + 1) * self.cols]
                    .iter()
                    .zip(x)
                    .map(|(a, b)| a * b)
                    .sum()
            })
            .collect()
    }

    pub fn matvec_t(&self, y: &[f64]) -> Vec<f64> {
        assert_eq!(self.rows, y.len());
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            let yi = y[i];
            if yi == 0.0 {
                continue;
            }
            for j in 0..self.cols {
                out[j] += self.at(i, j) * yi;
            }
        }
        out
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        }
    }

    pub fn scale(&self, s: f64) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|a| a * s).collect(),
        }
    }

    pub fn fro(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }
}

pub fn norm(x: &[f64]) -> f64 {
    x.iter().map(|v| v * v).sum::<f64>().sqrt()
}

pub fn normalize(x: &mut [f64]) -> f64 {
    let n = norm(x);
    if n > 0.0 {
        for v in x.iter_mut() {
            *v /= n;
        }
    }
    n
}

/// Spectral norm via power iteration on an implicit operator
/// (matvec, matvec_t) : R^n -> R^m — mirrors the in-graph telemetry so the
/// Rust tests can cross-check HLO-computed values.
pub fn spectral_norm_op(
    matvec: impl Fn(&[f64]) -> Vec<f64>,
    matvec_t: impl Fn(&[f64]) -> Vec<f64>,
    n: usize,
    iters: usize,
    rng: &mut crate::util::rng::Pcg64,
) -> f64 {
    let mut v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    normalize(&mut v);
    let mut sigma = 0.0;
    for _ in 0..iters {
        let mut u = matvec(&v);
        normalize(&mut u);
        v = matvec_t(&u);
        sigma = normalize(&mut v);
    }
    sigma
}

pub fn spectral_norm(m: &Mat, iters: usize, rng: &mut crate::util::rng::Pcg64) -> f64 {
    spectral_norm_op(|x| m.matvec(x), |y| m.matvec_t(y), m.cols, iters, rng)
}

/// Newton-Schulz orthogonalization — host mirror of the L1 kernel, same
/// coefficients (Jordan et al. 2024). Used only in tests to cross-validate
/// numerics between layers.
pub const NS_COEFFS: (f64, f64, f64) = (3.4445, -4.7750, 2.0315);

pub fn newton_schulz(g: &Mat, steps: usize) -> Mat {
    let (a, b, c) = NS_COEFFS;
    let transposed = g.rows < g.cols;
    let mut x = if transposed { g.t() } else { g.clone() };
    let f = x.fro() + 1e-7;
    x = x.scale(1.0 / f);
    for _ in 0..steps {
        let gram = x.t().matmul(&x);
        let gram2 = gram.matmul(&gram);
        let mut bmat = gram.scale(b);
        for (o, g2) in bmat.data.iter_mut().zip(&gram2.data) {
            *o += c * g2;
        }
        let xb = x.matmul(&bmat);
        x = x.scale(a);
        for (o, v) in x.data.iter_mut().zip(&xb.data) {
            *o += v;
        }
    }
    if transposed {
        x.t()
    } else {
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn matmul_identity() {
        let mut rng = Pcg64::new(0);
        let a = Mat::randn(5, 7, &mut rng);
        let mut eye = Mat::zeros(7, 7);
        for i in 0..7 {
            *eye.at_mut(i, i) = 1.0;
        }
        assert_eq!(a.matmul(&eye).data, a.data);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Pcg64::new(1);
        let a = Mat::randn(6, 4, &mut rng);
        let x: Vec<f64> = (0..4).map(|_| rng.normal()).collect();
        let xm = Mat { rows: 4, cols: 1, data: x.clone() };
        let want = a.matmul(&xm).data;
        assert_eq!(a.matvec(&x), want);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::new(2);
        let a = Mat::randn(3, 8, &mut rng);
        assert_eq!(a.t().t(), a);
    }

    #[test]
    fn spectral_norm_of_diagonal() {
        let mut m = Mat::zeros(4, 4);
        for (i, s) in [3.0, 7.0, 1.0, 5.0].iter().enumerate() {
            *m.at_mut(i, i) = *s;
        }
        let mut rng = Pcg64::new(3);
        let s = spectral_norm(&m, 50, &mut rng);
        assert!((s - 7.0).abs() < 1e-6, "{s}");
    }

    #[test]
    fn spectral_norm_rank1_product_op() {
        // ||a bᵀ||_2 = |a||b|, computed through the implicit factored op
        let a = vec![1.0, 2.0, 2.0]; // |a| = 3
        let b = vec![3.0, 4.0]; // |b| = 5
        let mv = |x: &[f64]| -> Vec<f64> {
            let s: f64 = b.iter().zip(x).map(|(p, q)| p * q).sum();
            a.iter().map(|ai| ai * s).collect()
        };
        let mt = |y: &[f64]| -> Vec<f64> {
            let s: f64 = a.iter().zip(y).map(|(p, q)| p * q).sum();
            b.iter().map(|bi| bi * s).collect()
        };
        let mut rng = Pcg64::new(4);
        let s = spectral_norm_op(mv, mt, 2, 30, &mut rng);
        assert!((s - 15.0).abs() < 1e-9, "{s}");
    }

    #[test]
    fn newton_schulz_orthogonalizes() {
        let mut rng = Pcg64::new(5);
        let g = Mat::randn(32, 8, &mut rng);
        let o = newton_schulz(&g, 5);
        // OᵀO ≈ I within the Jordan-coefficient band: the quintic pushes
        // singular values into roughly [0.7, 1.2] after 5 iterations, so
        // diagonal entries (σ²) live in ~[0.49, 1.45] and off-diagonals
        // stay small relative to the diagonal.
        let gram = o.t().matmul(&o);
        for i in 0..8 {
            let d = gram.at(i, i);
            assert!((0.4..1.5).contains(&d), "gram[{i}][{i}] = {d}");
            for j in 0..8 {
                if i != j {
                    assert!(gram.at(i, j).abs() < 0.35, "gram[{i}][{j}] = {}", gram.at(i, j));
                }
            }
        }
        let mut rng2 = Pcg64::new(6);
        let s = spectral_norm(&o, 40, &mut rng2);
        assert!(s < 1.35 && s > 0.6, "{s}");
    }

    /// Naive references for the tiled kernels: the blocked versions must
    /// be bit-identical (same per-element accumulation order), not just
    /// close — the Newton-Schulz cross-layer mirrors rely on it.
    fn t_naive(m: &Mat) -> Mat {
        let mut out = Mat::zeros(m.cols, m.rows);
        for i in 0..m.rows {
            for j in 0..m.cols {
                *out.at_mut(j, i) = m.at(i, j);
            }
        }
        out
    }

    fn matmul_naive(a: &Mat, b: &Mat) -> Mat {
        let mut out = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for k in 0..a.cols {
                let v = a.at(i, k);
                if v == 0.0 {
                    continue;
                }
                for j in 0..b.cols {
                    out.data[i * b.cols + j] += v * b.data[k * b.cols + j];
                }
            }
        }
        out
    }

    #[test]
    fn tiled_kernels_bit_match_naive_across_block_edges() {
        let mut rng = Pcg64::new(42);
        // shapes below, at, and straddling the 64-wide tile edge
        for (m, k, n) in [(3, 5, 4), (64, 64, 64), (70, 130, 65), (1, 200, 1), (129, 64, 63)] {
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let t_want = t_naive(&a);
            let t_got = a.t();
            assert_eq!(t_want.rows, t_got.rows);
            for (x, y) in t_want.data.iter().zip(&t_got.data) {
                assert_eq!(x.to_bits(), y.to_bits(), "t() drifted at {m}x{k}");
            }
            let mm_want = matmul_naive(&a, &b);
            let mm_got = a.matmul(&b);
            for (x, y) in mm_want.data.iter().zip(&mm_got.data) {
                assert_eq!(x.to_bits(), y.to_bits(), "matmul drifted at {m}x{k}x{n}");
            }
        }
    }

    #[test]
    fn newton_schulz_wide_matches_tall() {
        let mut rng = Pcg64::new(7);
        let g = Mat::randn(8, 32, &mut rng);
        let o_wide = newton_schulz(&g, 5);
        let o_tall = newton_schulz(&g.t(), 5).t();
        for (a, b) in o_wide.data.iter().zip(&o_tall.data) {
            assert!((a - b).abs() < 1e-12);
        }
    }
}
