//! The L3 hot loop: thread the state buffer through the backend's `step`
//! program, handing over only the token batch each step and reading the
//! state back every `read_interval` steps (the loss ring recovers the
//! per-step curve in between).
//!
//! The loop is backend-agnostic (DESIGN.md §Backends): under PJRT the
//! state stays device-resident and token uploads ride the staging pool
//! (DESIGN.md §Hot-loop pipeline, with the periodic state sync as the
//! retire fence); natively the same calls interpret the state on the
//! host. Batches arrive through the [`BatchSource`] abstraction (the
//! synchronous iterator or the async prefetch ring, byte-identical
//! streams) either way.

use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::config::{RunCfg, VariantCfg};
use crate::data::dataset::BatchSource;
use crate::monitor::{Directive, NullObserver, StepObserver};
use crate::runtime::backend::{Backend, StateBuf};
use crate::runtime::state as slots;
use crate::runtime::{ArtifactIndex, Manifest, NativeBackend, PjrtBackend, Runtime, StateHost};
use crate::train::metrics::{MetricsLog, Record};

pub struct Trainer {
    backend: Box<dyn Backend>,
    pub manifest: Manifest,
    pub variant: VariantCfg,
    pub run: RunCfg,
    state_buf: StateBuf,
    last_host: StateHost,
    last_ring_step: usize,
}

#[derive(Debug)]
pub struct TrainResult {
    pub losses: Vec<(usize, f32)>,
    pub records: Vec<Record>,
    pub final_loss: f64,
    pub diverged: bool,
    /// a [`StepObserver`] directive (or the re-run budget) stopped the
    /// run before it reached its step target
    pub halted: bool,
    pub wall_s: f64,
    pub steps_done: usize,
    pub tokens_seen: f64,
    pub step_seconds_mean: f64,
}

impl Trainer {
    /// PJRT path: compile programs and run `init` (knobs land in the
    /// state header).
    pub fn new(
        rt: &Runtime,
        idx: &ArtifactIndex,
        variant: &VariantCfg,
        run: RunCfg,
    ) -> Result<Trainer> {
        let backend = Box::new(PjrtBackend::new(rt, idx, &variant.name)?);
        Self::with_backend(backend, variant, run)
    }

    /// Native path: no artifacts, no PJRT — the zero-dependency fallback.
    /// Tensor-core budget from `REPRO_THREADS` (else serial).
    pub fn native(variant: &VariantCfg, run: RunCfg) -> Result<Trainer> {
        Self::with_backend(Box::new(NativeBackend::new(variant)?), variant, run)
    }

    /// [`Trainer::native`] with an explicit tensor-core thread budget
    /// (`--threads`; bit-identical at every value,
    /// DESIGN.md §Native tensor core).
    pub fn native_with_threads(
        variant: &VariantCfg,
        run: RunCfg,
        threads: usize,
    ) -> Result<Trainer> {
        Self::with_backend(
            Box::new(NativeBackend::with_threads(variant, threads)?),
            variant,
            run,
        )
    }

    /// Any backend: run `init` and mirror the fresh state to the host.
    pub fn with_backend(
        mut backend: Box<dyn Backend>,
        variant: &VariantCfg,
        run: RunCfg,
    ) -> Result<Trainer> {
        Self::check_step(backend.as_ref())?;
        let knobs = slots::knobs(&run);
        let state_buf = backend.init(run.seed, &knobs)?;
        let manifest = backend.manifest().clone();
        let host = StateHost::new(backend.download(&state_buf)?, &manifest)?;
        Ok(Trainer {
            backend,
            manifest,
            variant: variant.clone(),
            run,
            state_buf,
            last_host: host,
            last_ring_step: 0,
        })
    }

    /// Resume from a checkpointed state vector (PJRT). The upload is
    /// staged — the source literal stays pinned inside the backend until
    /// the first state readback fences it.
    pub fn from_state(
        rt: &Runtime,
        idx: &ArtifactIndex,
        variant: &VariantCfg,
        run: RunCfg,
        state: Vec<f32>,
    ) -> Result<Trainer> {
        let backend = Box::new(PjrtBackend::new(rt, idx, &variant.name)?);
        Self::from_state_backend(backend, variant, run, state)
    }

    /// Resume on any backend.
    pub fn from_state_backend(
        mut backend: Box<dyn Backend>,
        variant: &VariantCfg,
        run: RunCfg,
        state: Vec<f32>,
    ) -> Result<Trainer> {
        Self::check_step(backend.as_ref())?;
        let manifest = backend.manifest().clone();
        if state.len() != manifest.state_len {
            return Err(anyhow!("checkpoint length mismatch"));
        }
        let state_buf = backend.upload_state(&state)?;
        // the checkpoint vector itself becomes the host mirror — no clone
        let host = StateHost::new(state, &manifest)?;
        let last_ring_step = host.step();
        Ok(Trainer {
            backend,
            manifest,
            variant: variant.clone(),
            run,
            state_buf,
            last_host: host,
            last_ring_step,
        })
    }

    /// Fail fast when the backend cannot train this variant (e.g. the
    /// native backend's selfguided restriction, advertised by the
    /// manifest's program map) — before any data prep happens.
    fn check_step(backend: &dyn Backend) -> Result<()> {
        let m = backend.manifest();
        anyhow::ensure!(
            m.programs.is_empty() || m.programs.contains_key("step"),
            "variant {} has no step program on the {} backend",
            m.variant,
            backend.kind()
        );
        Ok(())
    }

    pub fn state(&self) -> &StateHost {
        &self.last_host
    }

    pub fn backend_kind(&self) -> crate::runtime::BackendKind {
        self.backend.kind()
    }

    /// Force a state readback now (updates `state()`). On PJRT the
    /// readback is also the fence that retires staged uploads; the
    /// backend quarantines them internally if it fails.
    pub fn sync(&mut self) -> Result<&StateHost> {
        let data = self.backend.download(&self.state_buf)?;
        self.last_host = StateHost::new(data, &self.manifest)?;
        Ok(&self.last_host)
    }

    /// Run `n_steps` training steps pulling batches from `batches`.
    /// Stops early (with `diverged = true`) if the loss goes non-finite or
    /// explodes; that is an observation, not an error — the lr-stability
    /// figures rely on recording divergence.
    pub fn train<B: BatchSource>(&mut self, batches: &mut B, n_steps: usize) -> Result<TrainResult> {
        self.train_with(batches, n_steps, &mut MetricsLog::in_memory(&self.variant.name))
    }

    pub fn train_with<B: BatchSource>(
        &mut self,
        batches: &mut B,
        n_steps: usize,
        metrics: &mut MetricsLog,
    ) -> Result<TrainResult> {
        self.train_observed(batches, n_steps, metrics, &mut NullObserver)
    }

    /// [`Trainer::train_with`] plus a [`StepObserver`] consulted after
    /// every state readback (DESIGN.md §Monitoring and sweeps). The
    /// observer sees each fresh [`Record`] and can direct the loop:
    /// `Halt` stops it (`halted = true`), `CutLr` rewrites the header
    /// `base_lr` and re-uploads, `Rollback` restores a healthy state and
    /// re-runs the window on fresh batches (the offending window is
    /// skipped because the batch stream does not rewind). With the
    /// [`NullObserver`] the loop is behaviorally identical to the
    /// unmonitored path.
    pub fn train_observed<B: BatchSource>(
        &mut self,
        batches: &mut B,
        n_steps: usize,
        metrics: &mut MetricsLog,
        observer: &mut dyn StepObserver,
    ) -> Result<TrainResult> {
        let read_every = self.run.read_interval.clamp(1, slots::RING);
        // step-counter handle cached once, not per step (DESIGN.md
        // §Observability); spans below only time phase boundaries — they
        // never touch batch or state data, so observed training stays
        // bit-identical to unobserved (docs/adr/009)
        let steps_total = crate::obs::global().counter("train_steps_total", &[]);
        let t0 = Instant::now();
        let mut diverged = false;
        let mut halted = false;
        let mut steps_done = 0;
        let mut all_losses: Vec<(usize, f32)> = Vec::new();
        let mut all_records: Vec<Record> = Vec::new();

        let start_step = self.last_host.step();
        let target = start_step + n_steps;
        // rollbacks re-run their window, so executions can exceed
        // n_steps; bound them so repeated spikes cannot loop forever
        // (the monitor's own intervention cap normally halts first)
        let max_exec = n_steps.saturating_mul(4).max(n_steps.saturating_add(64));
        let mut cur = start_step;
        while cur < target {
            if steps_done >= max_exec {
                crate::info!("train", "re-run budget exhausted ({max_exec} steps executed)");
                // refresh the host mirror so the result (and any
                // checkpoint a caller takes) reflects the steps that
                // actually ran since the last readback
                self.sync()?;
                self.last_ring_step = self.last_host.step();
                halted = true;
                break;
            }
            let batch = {
                let _sp = crate::obs::Span::begin("prefetch_wait", "train");
                batches.next_batch_ref()
            };
            let out = {
                let _sp = crate::obs::Span::begin("step", "train")
                    .arg("step", cur as f64);
                self.backend.step(&self.state_buf, batch)?
            };
            self.state_buf = out;
            steps_done += 1;
            cur += 1;
            steps_total.inc();

            if cur % read_every == 0 || cur == target {
                let _sp = crate::obs::Span::begin("telemetry", "train")
                    .arg("step", cur as f64);
                self.sync()?;
                let host = &self.last_host;
                let ring = host.ring_losses(self.last_ring_step);
                self.last_ring_step = host.step();
                let rec = crate::monitor::record_from_host(host, t0.elapsed().as_secs_f64());
                all_losses.extend(ring.iter().copied());
                all_records.push(rec.clone());
                let directive = observer.observe(host, &rec, &ring);
                metrics.push(rec, ring);
                match directive {
                    Directive::Continue => {
                        if !host.is_finite() || host.loss() > 30.0 {
                            diverged = true;
                            break;
                        }
                    }
                    Directive::Halt { reason } => {
                        crate::info!("train", "observer halt: {reason}");
                        halted = true;
                        break;
                    }
                    Directive::CutLr { factor } => {
                        observer.applied(&Directive::CutLr { factor });
                        let mut data = self.last_host.data.clone();
                        data[slots::BASE_LR] *= factor as f32;
                        self.state_buf = self.backend.upload_state(&data)?;
                        self.last_host = StateHost::new(data, &self.manifest)?;
                    }
                    Directive::Rollback { to_step, state, skip_batches } => {
                        crate::info!(
                            "train",
                            "rolling back {} -> {} (skip {} batches)",
                            cur,
                            to_step,
                            skip_batches
                        );
                        self.state_buf = self.backend.upload_state(&state)?;
                        self.last_host = StateHost::new(state, &self.manifest)?;
                        self.last_ring_step = self.last_host.step();
                        cur = self.last_host.step();
                        for _ in 0..skip_batches {
                            let _ = batches.next_batch_ref();
                        }
                        observer.applied(&Directive::Rollback {
                            to_step,
                            state: Vec::new(), // notification only
                            skip_batches,
                        });
                    }
                }
            }
        }
        metrics.flush();
        let wall = t0.elapsed().as_secs_f64();
        let final_loss = all_records.last().map(|r| r.loss).unwrap_or(f64::NAN);
        Ok(TrainResult {
            losses: all_losses,
            records: all_records,
            final_loss,
            diverged,
            halted,
            wall_s: wall,
            steps_done,
            tokens_seen: self.last_host.tokens_seen(),
            step_seconds_mean: wall / steps_done.max(1) as f64,
        })
    }

    /// Current state vector (host copy) for checkpointing: one readback,
    /// returned directly. Callers that only inspect should use the
    /// by-ref [`Trainer::state_ref`] (or [`Trainer::sync`]) instead.
    pub fn state_vec(&mut self) -> Result<Vec<f32>> {
        self.backend.download(&self.state_buf)
    }

    /// Fresh state readback, lent by reference (also updates `state()`).
    pub fn state_ref(&mut self) -> Result<&[f32]> {
        Ok(&self.sync()?.data)
    }
}
