//! The L3 hot loop: thread the state buffer through the compiled `step`
//! program, uploading only the token batch each step and reading the state
//! back every `read_interval` steps (the loss ring recovers the per-step
//! curve in between).
//!
//! The loop is pipelined and allocation-free in the steady state
//! (DESIGN.md §Hot-loop pipeline): batches arrive through the
//! [`BatchSource`] abstraction (the synchronous iterator or the async
//! prefetch ring, byte-identical streams), token uploads go through a
//! [`client::StagingPool`] so no per-step sync readback or literal churn
//! remains, and the periodic state sync doubles as the fence that retires
//! staged uploads.

use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::config::{RunCfg, VariantCfg};
use crate::data::dataset::BatchSource;
use crate::runtime::state as slots;
use crate::runtime::{client, ArtifactIndex, Manifest, Program, Runtime, StateHost};
use crate::train::metrics::{MetricsLog, Record};

pub struct Trainer {
    pub rt: Runtime,
    pub manifest: Manifest,
    pub variant: VariantCfg,
    pub run: RunCfg,
    step_prog: std::sync::Arc<Program>,
    state_buf: xla::PjRtBuffer,
    staging: client::StagingPool,
    last_host: StateHost,
    last_ring_step: usize,
}

#[derive(Debug)]
pub struct TrainResult {
    pub losses: Vec<(usize, f32)>,
    pub records: Vec<Record>,
    pub final_loss: f64,
    pub diverged: bool,
    pub wall_s: f64,
    pub steps_done: usize,
    pub tokens_seen: f64,
    pub step_seconds_mean: f64,
}

impl Trainer {
    /// Compile programs and run `init` (knobs land in the state header).
    pub fn new(
        rt: &Runtime,
        idx: &ArtifactIndex,
        variant: &VariantCfg,
        run: RunCfg,
    ) -> Result<Trainer> {
        let manifest = idx.manifest(&variant.name)?;
        let init = rt.load_program(&idx.program_path(&variant.name, "init"))?;
        let step_prog = rt.load_program(&idx.program_path(&variant.name, "step"))?;

        let knobs = slots::knobs(&run);
        let out = init
            .run_literals(&[client::scalar_i32(run.seed as i32), client::vec_f32(&knobs)])
            .context("init program")?;
        let host = StateHost::new(rt.download_f32(&out)?, &manifest)?;
        Ok(Trainer {
            rt: rt.clone(),
            manifest,
            variant: variant.clone(),
            run,
            step_prog,
            state_buf: out,
            staging: client::StagingPool::new(),
            last_host: host,
            last_ring_step: 0,
        })
    }

    /// Resume from a checkpointed state vector. The upload is staged — the
    /// source literal stays alive in the trainer's pool until the first
    /// state readback fences it — so resume pays neither the old
    /// belt-and-braces full-state readback nor an extra host copy of the
    /// checkpoint vector.
    pub fn from_state(
        rt: &Runtime,
        idx: &ArtifactIndex,
        variant: &VariantCfg,
        run: RunCfg,
        state: Vec<f32>,
    ) -> Result<Trainer> {
        let manifest = idx.manifest(&variant.name)?;
        if state.len() != manifest.state_len {
            return Err(anyhow!("checkpoint length mismatch"));
        }
        let step_prog = rt.load_program(&idx.program_path(&variant.name, "step"))?;
        let mut staging = client::StagingPool::new();
        let state_buf = staging.upload_f32(rt, &state)?;
        // the checkpoint vector itself becomes the host mirror — no clone
        let host = StateHost::new(state, &manifest)?;
        let last_ring_step = host.step();
        Ok(Trainer {
            rt: rt.clone(),
            manifest,
            variant: variant.clone(),
            run,
            step_prog,
            state_buf,
            staging,
            last_host: host,
            last_ring_step,
        })
    }

    pub fn state(&self) -> &StateHost {
        &self.last_host
    }

    /// Force a state readback now (updates `state()`). The readback also
    /// proves every staged upload was consumed, so the pool retires; if
    /// the readback itself fails, the fence never happened and the staged
    /// literals are quarantined (leaked) instead of freed later.
    pub fn sync(&mut self) -> Result<&StateHost> {
        match self.rt.download_f32(&self.state_buf) {
            Ok(data) => {
                self.staging.retire();
                self.last_host = StateHost::new(data, &self.manifest)?;
                Ok(&self.last_host)
            }
            Err(e) => {
                self.staging.quarantine();
                Err(e)
            }
        }
    }

    /// Run `n_steps` training steps pulling batches from `batches`.
    /// Stops early (with `diverged = true`) if the loss goes non-finite or
    /// explodes past `20 + initial`; that is an observation, not an error —
    /// the lr-stability figures rely on recording divergence.
    pub fn train<B: BatchSource>(&mut self, batches: &mut B, n_steps: usize) -> Result<TrainResult> {
        self.train_with(batches, n_steps, &mut MetricsLog::in_memory(&self.variant.name))
    }

    pub fn train_with<B: BatchSource>(
        &mut self,
        batches: &mut B,
        n_steps: usize,
        metrics: &mut MetricsLog,
    ) -> Result<TrainResult> {
        let res = self.train_with_inner(batches, n_steps, metrics);
        if res.is_err() {
            // an error mid-loop (failed upload/execute/readback) can
            // leave staged uploads unfenced; a later retire must not
            // free them (StagingPool contract)
            self.staging.quarantine();
        }
        res
    }

    fn train_with_inner<B: BatchSource>(
        &mut self,
        batches: &mut B,
        n_steps: usize,
        metrics: &mut MetricsLog,
    ) -> Result<TrainResult> {
        let b = self.manifest.batch;
        let w = self.manifest.seq_len + 1;
        let read_every = self.run.read_interval.clamp(1, slots::RING);
        let t0 = Instant::now();
        let mut diverged = false;
        let mut steps_done = 0;
        let mut all_losses: Vec<(usize, f32)> = Vec::new();
        let mut all_records: Vec<Record> = Vec::new();

        for k in 0..n_steps {
            let batch = batches.next_batch_ref();
            // staged upload: the literal is parked in the pool until the
            // next sync's readback proves the async copy was consumed
            let tok = self.staging.upload_tokens(&self.rt, batch, b, w).context("upload tokens")?;
            let out = self.step_prog.run_buffers(&[&self.state_buf, &tok])?;
            self.state_buf = out;
            steps_done = k + 1;

            let is_last = k + 1 == n_steps;
            if (k + 1) % read_every == 0 || is_last {
                self.sync()?;
                let host = &self.last_host;
                let ring = host.ring_losses(self.last_ring_step);
                self.last_ring_step = host.step();
                let rec = Record {
                    step: host.step(),
                    loss: host.loss() as f64,
                    lr: host.lr() as f64,
                    grad_norm: host.grad_norm() as f64,
                    tokens_seen: host.tokens_seen(),
                    telemetry: host.telemetry(),
                    wall_s: t0.elapsed().as_secs_f64(),
                };
                all_losses.extend(ring.iter().copied());
                all_records.push(rec.clone());
                metrics.push(rec, ring);
                if !host.is_finite() || host.loss() > 30.0 {
                    diverged = true;
                    break;
                }
            }
        }
        metrics.flush();
        let wall = t0.elapsed().as_secs_f64();
        let final_loss = all_records.last().map(|r| r.loss).unwrap_or(f64::NAN);
        Ok(TrainResult {
            losses: all_losses,
            records: all_records,
            final_loss,
            diverged,
            wall_s: wall,
            steps_done,
            tokens_seen: self.last_host.tokens_seen(),
            step_seconds_mean: wall / steps_done.max(1) as f64,
        })
    }

    /// Current state vector (host copy) for checkpointing: one readback,
    /// returned directly — no second full-state allocation. Callers that
    /// only inspect should use the by-ref [`Trainer::state_ref`] (or
    /// [`Trainer::sync`]) instead.
    pub fn state_vec(&mut self) -> Result<Vec<f32>> {
        match self.rt.download_f32(&self.state_buf) {
            Ok(data) => {
                self.staging.retire();
                Ok(data)
            }
            Err(e) => {
                self.staging.quarantine();
                Err(e)
            }
        }
    }

    /// Fresh state readback, lent by reference (also updates `state()`).
    pub fn state_ref(&mut self) -> Result<&[f32]> {
        Ok(&self.sync()?.data)
    }
}
