//! Checkpointing: the entire run is one flat f32 vector, so a checkpoint
//! is that vector plus identifying metadata. Binary format:
//!
//! ```text
//! magic "SPCKPT01" | name_len u32 LE | variant name utf-8 |
//! state_len u64 LE | f32 LE data ... | crc64 of data
//! ```

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, Context, Result};

const MAGIC: &[u8; 8] = b"SPCKPT01";

pub fn save(path: &Path, variant: &str, state: &[f32]) -> Result<()> {
    let _sp = crate::obs::Span::begin("checkpoint", "train")
        .arg("len", state.len() as f64);
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&(variant.len() as u32).to_le_bytes())?;
    w.write_all(variant.as_bytes())?;
    w.write_all(&(state.len() as u64).to_le_bytes())?;
    let mut crc = Crc64::new();
    for v in state {
        let b = v.to_le_bytes();
        crc.update(&b);
        w.write_all(&b)?;
    }
    w.write_all(&crc.finish().to_le_bytes())?;
    w.flush()?;
    Ok(())
}

/// Parse the fixed header (magic + variant name); shared by `load` and
/// `peek_variant` so a format change can't drift between them.
fn read_header(r: &mut impl Read) -> Result<String> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(anyhow!("not a spectron checkpoint: bad magic"));
    }
    let mut u32b = [0u8; 4];
    r.read_exact(&mut u32b)?;
    let name_len = u32::from_le_bytes(u32b) as usize;
    if name_len > 4096 {
        return Err(anyhow!("implausible variant name length {name_len}"));
    }
    let mut name = vec![0u8; name_len];
    r.read_exact(&mut name)?;
    String::from_utf8(name).context("variant name utf-8")
}

pub fn load(path: &Path) -> Result<(String, Vec<f32>)> {
    let mut r = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
    );
    let variant = read_header(&mut r)?;
    let mut u64b = [0u8; 8];
    r.read_exact(&mut u64b)?;
    let n = u64::from_le_bytes(u64b) as usize;
    let mut state = Vec::with_capacity(n);
    let mut crc = Crc64::new();
    let mut buf = [0u8; 4];
    for _ in 0..n {
        r.read_exact(&mut buf)?;
        crc.update(&buf);
        state.push(f32::from_le_bytes(buf));
    }
    r.read_exact(&mut u64b)?;
    if u64::from_le_bytes(u64b) != crc.finish() {
        return Err(anyhow!("checkpoint corrupt: crc mismatch"));
    }
    Ok((variant, state))
}

/// Read just the variant name from a checkpoint header — the serve
/// launcher maps `--ckpt` files to variants without pulling whole state
/// vectors into memory at startup.
pub fn peek_variant(path: &Path) -> Result<String> {
    let mut r = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
    );
    read_header(&mut r)
}

/// Rolling retention: a directory of `step-<N>.ckpt` files, pruned to
/// the newest `keep`. The stability monitor snapshots healthy states
/// here so `rollback` has somewhere to go, and a crashed sweep run
/// resumes from [`RollingCheckpoints::load_latest`]
/// (DESIGN.md §Monitoring and sweeps). Writes are tmp+rename so a crash
/// mid-save can never replace a good checkpoint with a torn one.
pub struct RollingCheckpoints {
    dir: std::path::PathBuf,
    variant: String,
    keep: usize,
}

impl RollingCheckpoints {
    pub fn new(dir: impl Into<std::path::PathBuf>, variant: &str, keep: usize) -> Result<Self> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir).context("mkdir checkpoint dir")?;
        Ok(RollingCheckpoints { dir, variant: variant.to_string(), keep: keep.max(1) })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Save `state` as `step-<step>.ckpt` and prune beyond the retention
    /// window. Re-saving the same step overwrites (idempotent resume).
    pub fn save(&self, step: usize, state: &[f32]) -> Result<std::path::PathBuf> {
        let path = self.dir.join(format!("step-{step}.ckpt"));
        let tmp = self.dir.join(format!(".step-{step}.ckpt.tmp"));
        save(&tmp, &self.variant, state)?;
        std::fs::rename(&tmp, &path).context("commit checkpoint")?;
        // prune oldest files beyond the window
        let mut all = self.list();
        while all.len() > self.keep {
            let (_, oldest) = all.remove(0);
            std::fs::remove_file(oldest).ok();
        }
        Ok(path)
    }

    /// `(step, path)` pairs, oldest first.
    fn list(&self) -> Vec<(usize, std::path::PathBuf)> {
        let mut out = Vec::new();
        let Ok(entries) = std::fs::read_dir(&self.dir) else { return out };
        for e in entries.flatten() {
            let name = e.file_name();
            let name = name.to_string_lossy();
            if let Some(step) = name
                .strip_prefix("step-")
                .and_then(|s| s.strip_suffix(".ckpt"))
                .and_then(|s| s.parse::<usize>().ok())
            {
                out.push((step, e.path()));
            }
        }
        out.sort_by_key(|(s, _)| *s);
        out
    }

    pub fn latest(&self) -> Option<(usize, std::path::PathBuf)> {
        self.list().pop()
    }

    /// Load the newest retained checkpoint, skipping over corrupt files
    /// (a crash can tear at most the file being written, but belt and
    /// braces: the crc already detects torn data, so fall back to the
    /// next-newest rather than wedging the resume).
    pub fn load_latest(&self) -> Result<Option<(usize, Vec<f32>)>> {
        let mut all = self.list();
        while let Some((step, path)) = all.pop() {
            match load(&path) {
                Ok((v, state)) if v == self.variant => return Ok(Some((step, state))),
                Ok((v, _)) => {
                    return Err(anyhow!(
                        "checkpoint {} is for variant '{v}', expected '{}'",
                        path.display(),
                        self.variant
                    ))
                }
                Err(e) => {
                    crate::info!("ckpt", "skipping corrupt {}: {e:#}", path.display());
                    continue;
                }
            }
        }
        Ok(None)
    }
}

/// CRC-64/XZ, bitwise (checkpoints are not huge; simplicity wins).
struct Crc64 {
    crc: u64,
}

impl Crc64 {
    fn new() -> Crc64 {
        Crc64 { crc: !0 }
    }
    fn update(&mut self, data: &[u8]) {
        for &b in data {
            self.crc ^= b as u64;
            for _ in 0..8 {
                let mask = (self.crc & 1).wrapping_neg();
                self.crc = (self.crc >> 1) ^ (0xC96C5795D7870F42 & mask);
            }
        }
    }
    fn finish(&self) -> u64 {
        !self.crc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("spectron-ckpt-test-{name}-{}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let p = tmp("rt");
        let state: Vec<f32> = (0..1000).map(|i| (i as f32).sin()).collect();
        save(&p, "fact-s-spectron", &state).unwrap();
        let (v, s) = load(&p).unwrap();
        assert_eq!(v, "fact-s-spectron");
        assert_eq!(s, state);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn detects_corruption() {
        let p = tmp("corrupt");
        save(&p, "x", &[1.0, 2.0, 3.0]).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let n = bytes.len();
        bytes[n - 12] ^= 0xFF; // flip a data byte
        std::fs::write(&p, &bytes).unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn peek_reads_variant_without_state() {
        let p = tmp("peek");
        save(&p, "fact-s-spectron", &[0.5; 64]).unwrap();
        assert_eq!(peek_variant(&p).unwrap(), "fact-s-spectron");
        std::fs::remove_file(&p).ok();
        assert!(peek_variant(&p).is_err());
    }

    #[test]
    fn rolling_retention_prunes_and_resumes() {
        let dir = std::env::temp_dir().join(format!("spectron-roll-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let roll = RollingCheckpoints::new(&dir, "v", 3).unwrap();
        assert!(roll.latest().is_none());
        assert!(roll.load_latest().unwrap().is_none());
        for step in [5usize, 10, 15, 20, 25] {
            roll.save(step, &[step as f32; 16]).unwrap();
        }
        // only the newest 3 remain; latest is step 25
        assert_eq!(roll.list().len(), 3);
        assert_eq!(roll.list()[0].0, 15);
        let (step, state) = roll.load_latest().unwrap().unwrap();
        assert_eq!(step, 25);
        assert_eq!(state, vec![25.0f32; 16]);
        // corrupt the newest: load falls back to the next-newest
        std::fs::write(dir.join("step-25.ckpt"), b"torn").unwrap();
        let (step, state) = roll.load_latest().unwrap().unwrap();
        assert_eq!(step, 20);
        assert_eq!(state, vec![20.0f32; 16]);
        // wrong variant is a hard error, not a silent resume
        let other = RollingCheckpoints::new(&dir, "other", 3).unwrap();
        assert!(other.load_latest().is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_other_files() {
        let p = tmp("other");
        std::fs::write(&p, b"definitely not a checkpoint").unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }
}
