//! Host mirror of the in-graph lr / alpha schedules (python/compile/
//! optim.py). Used for logging, expected-lr assertions in tests, and the
//! experiment drivers' plots — the authoritative schedule runs in HLO.

#[derive(Debug, Clone, Copy)]
pub struct Schedule {
    pub total_steps: usize,
    pub base_lr: f64,
    pub warmup_frac: f64,
}

impl Schedule {
    pub fn lr_at(&self, step: usize) -> f64 {
        let t = step as f64;
        let total = (self.total_steps as f64).max(1.0);
        let warm = (self.warmup_frac * total).max(1.0);
        if t < warm {
            // clip: with fractional warm the last warmup step would overshoot
            self.base_lr * ((t + 1.0) / warm).min(1.0)
        } else {
            let prog = ((t - warm) / (total - warm).max(1.0)).clamp(0.0, 1.0);
            self.base_lr * 0.5 * (1.0 + (std::f64::consts::PI * prog).cos())
        }
    }

    /// Self-guided mixing coefficient (cosine 1 -> 0 over the first half).
    pub fn alpha_at(&self, step: usize) -> f64 {
        let half = (0.5 * self.total_steps as f64).max(1.0);
        let prog = (step as f64 / half).clamp(0.0, 1.0);
        0.5 * (1.0 + (std::f64::consts::PI * prog).cos())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_then_cosine_to_zero() {
        let s = Schedule { total_steps: 100, base_lr: 1.0, warmup_frac: 0.1 };
        assert!((s.lr_at(0) - 0.1).abs() < 1e-12);
        assert!((s.lr_at(9) - 1.0).abs() < 1e-12);
        assert!(s.lr_at(99) < 0.002);
        for t in 11..99 {
            assert!(s.lr_at(t) >= s.lr_at(t + 1) - 1e-12);
        }
    }

    #[test]
    fn alpha_halfway_zero() {
        let s = Schedule { total_steps: 100, base_lr: 1.0, warmup_frac: 0.05 };
        assert!((s.alpha_at(0) - 1.0).abs() < 1e-12);
        assert!((s.alpha_at(25) - 0.5).abs() < 1e-9);
        assert!(s.alpha_at(50).abs() < 1e-12);
        assert_eq!(s.alpha_at(80), 0.0);
    }
}
