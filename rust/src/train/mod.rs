//! Training runtime: the hot loop over the AOT-compiled `step` program.

pub mod checkpoint;
pub mod metrics;
pub mod schedule;
pub mod trainer;

pub use metrics::{MetricsLog, Record};
pub use trainer::{TrainResult, Trainer};
