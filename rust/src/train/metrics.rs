//! Metrics: in-memory records + JSONL sink under `results/`.

use std::io::Write;
use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One logged observation (a read-back of the state header).
#[derive(Debug, Clone)]
pub struct Record {
    pub step: usize,
    pub loss: f64,
    pub lr: f64,
    pub grad_norm: f64,
    pub tokens_seen: f64,
    /// [w_spec, dw_spec, dy_rms, sigma_a, sigma_b, rho]
    pub telemetry: [f32; 6],
    pub wall_s: f64,
}

impl Record {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("step", Json::num(self.step as f64)),
            ("loss", Json::num(self.loss)),
            ("lr", Json::num(self.lr)),
            ("grad_norm", Json::num(self.grad_norm)),
            ("tokens", Json::num(self.tokens_seen)),
            ("w_spec", Json::num(self.telemetry[0] as f64)),
            ("dw_spec", Json::num(self.telemetry[1] as f64)),
            ("dy_rms", Json::num(self.telemetry[2] as f64)),
            ("sigma_a", Json::num(self.telemetry[3] as f64)),
            ("sigma_b", Json::num(self.telemetry[4] as f64)),
            ("rho", Json::num(self.telemetry[5] as f64)),
            ("wall_s", Json::num(self.wall_s)),
        ])
    }
}

/// Collects records and per-step losses (ring-decoded); optionally tees
/// each record to a JSONL file.
pub struct MetricsLog {
    pub run_name: String,
    pub records: Vec<Record>,
    pub losses: Vec<(usize, f32)>,
    sink: Option<std::io::BufWriter<std::fs::File>>,
}

impl MetricsLog {
    pub fn in_memory(run_name: &str) -> MetricsLog {
        MetricsLog {
            run_name: run_name.to_string(),
            records: Vec::new(),
            losses: Vec::new(),
            sink: None,
        }
    }

    /// Tee to `results/<run_name>/metrics.jsonl` (truncating).
    pub fn with_file(run_name: &str) -> Result<MetricsLog> {
        Self::file_sink(run_name, false)
    }

    /// Like [`MetricsLog::with_file`] but appending — a resumed sweep run
    /// extends its own trail instead of erasing the pre-crash history.
    pub fn append_file(run_name: &str) -> Result<MetricsLog> {
        Self::file_sink(run_name, true)
    }

    fn file_sink(run_name: &str, append: bool) -> Result<MetricsLog> {
        let dir: PathBuf = crate::repo_path("results").join(run_name);
        std::fs::create_dir_all(&dir).context("mkdir results")?;
        let path = dir.join("metrics.jsonl");
        let f = std::fs::OpenOptions::new()
            .create(true)
            .append(append)
            .write(true)
            .truncate(!append)
            .open(path)?;
        let mut m = Self::in_memory(run_name);
        m.sink = Some(std::io::BufWriter::new(f));
        Ok(m)
    }

    pub fn push(&mut self, rec: Record, ring: Vec<(usize, f32)>) {
        if let Some(sink) = &mut self.sink {
            let _ = writeln!(sink, "{}", rec.to_json());
        }
        self.records.push(rec);
        self.losses.extend(ring);
    }

    /// Tee an arbitrary JSON row to the sink (no in-memory record). The
    /// serve telemetry logs per-batch rows this way so serving and
    /// training share one `results/<run>/metrics.jsonl` toolchain.
    pub fn log_json(&mut self, row: &Json) {
        if let Some(sink) = &mut self.sink {
            let _ = writeln!(sink, "{row}");
        }
    }

    /// Tee an event-class row (spike detections, interventions, run
    /// transitions) and flush immediately: a crash right after a spike
    /// must still leave the forensics trail on disk
    /// (DESIGN.md §Monitoring and sweeps).
    pub fn log_event(&mut self, row: &Json) {
        self.log_json(row);
        self.flush();
    }

    pub fn flush(&mut self) {
        if let Some(s) = &mut self.sink {
            let _ = s.flush();
        }
    }

    pub fn final_loss(&self) -> Option<f64> {
        self.records.last().map(|r| r.loss)
    }

    /// Smoothed loss curve (simple trailing mean over `w` points).
    pub fn smoothed_losses(&self, w: usize) -> Vec<(usize, f64)> {
        let w = w.max(1);
        self.losses
            .iter()
            .enumerate()
            .map(|(i, &(s, _))| {
                let lo = i.saturating_sub(w - 1);
                let vals: f64 = self.losses[lo..=i].iter().map(|&(_, l)| l as f64).sum();
                (s, vals / (i - lo + 1) as f64)
            })
            .collect()
    }
}

/// Dropping the log flushes the sink: a loop that errors out (or a run
/// torn down mid-panic-unwind) still lands its buffered records.
impl Drop for MetricsLog {
    fn drop(&mut self) {
        self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: usize, loss: f64) -> Record {
        Record {
            step,
            loss,
            lr: 0.01,
            grad_norm: 1.0,
            tokens_seen: 0.0,
            telemetry: [0.0; 6],
            wall_s: 0.0,
        }
    }

    #[test]
    fn collects_ring_losses_in_order() {
        let mut m = MetricsLog::in_memory("t");
        m.push(rec(2, 3.0), vec![(0, 5.0), (1, 4.0)]);
        m.push(rec(4, 2.0), vec![(2, 3.0), (3, 2.5)]);
        assert_eq!(m.losses.len(), 4);
        assert!(m.losses.windows(2).all(|w| w[0].0 < w[1].0));
        assert_eq!(m.final_loss(), Some(2.0));
    }

    #[test]
    fn smoothing_reduces_noise() {
        let mut m = MetricsLog::in_memory("t");
        let ring: Vec<(usize, f32)> =
            (0..100).map(|i| (i, 3.0 + if i % 2 == 0 { 0.5 } else { -0.5 })).collect();
        m.push(rec(100, 3.0), ring);
        let sm = m.smoothed_losses(10);
        let spread = sm[20..].iter().map(|&(_, l)| (l - 3.0).abs()).fold(0.0, f64::max);
        assert!(spread < 0.1, "{spread}");
    }

    #[test]
    fn log_json_without_sink_is_a_noop() {
        let mut m = MetricsLog::in_memory("t");
        m.log_json(&Json::obj(vec![("op", Json::str("generate"))]));
        assert!(m.records.is_empty() && m.losses.is_empty());
    }

    #[test]
    fn sink_flushes_on_event_and_on_drop() {
        let name = format!("metrics-test-{}", std::process::id());
        let dir = crate::repo_path("results").join(&name);
        let path = dir.join("metrics.jsonl");
        {
            let mut m = MetricsLog::with_file(&name).unwrap();
            m.push(rec(1, 3.0), vec![(0, 3.0)]);
            m.log_event(&Json::obj(vec![("event", Json::str("spike"))]));
            // the event flushed everything buffered before it
            let on_disk = std::fs::read_to_string(&path).unwrap();
            assert_eq!(on_disk.lines().count(), 2, "event rows must hit disk immediately");
            m.push(rec(2, 2.5), vec![(1, 2.5)]);
            // dropped without an explicit flush()
        }
        let on_disk = std::fs::read_to_string(&path).unwrap();
        assert_eq!(on_disk.lines().count(), 3, "drop must flush the tail");
        // append mode extends, truncate mode restarts
        {
            let mut m = MetricsLog::append_file(&name).unwrap();
            m.push(rec(3, 2.0), vec![(2, 2.0)]);
        }
        assert_eq!(std::fs::read_to_string(&path).unwrap().lines().count(), 4);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn jsonl_roundtrip() {
        let r = rec(7, 2.5);
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(j.get("step").unwrap().as_usize(), Some(7));
        assert_eq!(j.get("loss").unwrap().as_f64(), Some(2.5));
    }
}
