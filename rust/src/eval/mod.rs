//! Evaluation: validation perplexity and the synthetic downstream suites,
//! both driven through the shared `eval` program (one per architecture,
//! reused across optimizers — it consumes only the header+params prefix
//! of the state).

pub mod downstream;
pub mod perplexity;

use anyhow::{anyhow, Context, Result};

use crate::runtime::{client, ArtifactIndex, Manifest, Program, Runtime};

/// Handle on a compiled eval program plus its shapes.
pub struct Evaluator {
    rt: Runtime,
    prog: std::sync::Arc<Program>,
    pub batch: usize,
    pub seq_len: usize,
    pub params_end: usize,
}

impl Evaluator {
    pub fn new(rt: &Runtime, idx: &ArtifactIndex, manifest: &Manifest) -> Result<Evaluator> {
        let path = idx.eval_path(&manifest.eval_key);
        let prog = rt
            .load_program(&path)
            .with_context(|| format!("loading eval program {}", manifest.eval_key))?;
        Ok(Evaluator {
            rt: rt.clone(),
            prog,
            batch: manifest.batch,
            seq_len: manifest.seq_len,
            params_end: manifest.params_end,
        })
    }

    /// Score one batch. `tokens` is row-major (batch, seq_len+1); `spans`
    /// is (batch, 2) [start, end). Returns (total_nll, total_count,
    /// per_seq_nll, per_seq_count).
    pub fn score_batch(
        &self,
        prefix: &[f32],
        tokens: &[i32],
        spans: &[i32],
    ) -> Result<(f64, f64, Vec<f32>, Vec<f32>)> {
        if prefix.len() != self.params_end {
            return Err(anyhow!(
                "eval prefix length {} != {}",
                prefix.len(),
                self.params_end
            ));
        }
        let b = self.batch;
        let w = self.seq_len + 1;
        anyhow::ensure!(tokens.len() == b * w, "tokens shape");
        anyhow::ensure!(spans.len() == b * 2, "spans shape");
        let p_lit = client::vec_f32(prefix);
        let t_lit = client::tokens_literal(tokens, b, w)?;
        let s_lit = client::tokens_literal(spans, b, 2)?;
        let out = self.prog.run_literals(&[p_lit, t_lit, s_lit])?;
        self.unpack(&out)
    }

    /// Buffer-to-buffer variant for the serving hot path: the params
    /// prefix stays resident on device (uploaded once per
    /// [`crate::serve::session::ModelSession`]) instead of being
    /// re-uploaded per call as `score_batch` does.
    pub fn score_batch_buffers(
        &self,
        prefix: &xla::PjRtBuffer,
        tokens: &[i32],
        spans: &[i32],
    ) -> Result<(f64, f64, Vec<f32>, Vec<f32>)> {
        let b = self.batch;
        let w = self.seq_len + 1;
        anyhow::ensure!(tokens.len() == b * w, "tokens shape");
        anyhow::ensure!(spans.len() == b * 2, "spans shape");
        let t_buf = self.rt.upload_literal(&client::tokens_literal(tokens, b, w)?)?;
        let s_buf = self.rt.upload_literal(&client::tokens_literal(spans, b, 2)?)?;
        let out = self.prog.run_buffers(&[prefix, &t_buf, &s_buf])?;
        self.unpack(&out)
    }

    fn unpack(&self, out: &xla::PjRtBuffer) -> Result<(f64, f64, Vec<f32>, Vec<f32>)> {
        let b = self.batch;
        let v = self.rt.download_f32(out)?;
        anyhow::ensure!(v.len() == 2 + 2 * b, "eval output length {}", v.len());
        let nll = v[2..2 + b].to_vec();
        let cnt = v[2 + b..].to_vec();
        Ok((v[0] as f64, v[1] as f64, nll, cnt))
    }
}
