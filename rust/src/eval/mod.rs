//! Evaluation: validation perplexity and the synthetic downstream suites,
//! both driven through the shared `eval` program (one per architecture,
//! reused across optimizers — it consumes only the header+params prefix
//! of the state). Backend-agnostic: the same calls run the compiled HLO
//! under PJRT or the native interpreter (DESIGN.md §Backends).

pub mod downstream;
pub mod perplexity;

use std::cell::RefCell;

use anyhow::{anyhow, Context, Result};

use crate::config::VariantCfg;
use crate::runtime::backend::{Backend, DecodeModel, DecodeSession, StateBuf};
use crate::runtime::{ArtifactIndex, Manifest, NativeBackend, PjrtBackend, Runtime};

/// Handle on an eval-capable backend plus its shapes. Interior
/// mutability: scoring is logically read-only (`&self` everywhere), while
/// backends take `&mut self` for their transfer scratch.
pub struct Evaluator {
    backend: RefCell<Box<dyn Backend>>,
    pub batch: usize,
    pub seq_len: usize,
    pub params_end: usize,
}

impl Evaluator {
    /// PJRT path (requires artifacts).
    pub fn new(rt: &Runtime, idx: &ArtifactIndex, manifest: &Manifest) -> Result<Evaluator> {
        let backend = PjrtBackend::new(rt, idx, &manifest.variant)
            .with_context(|| format!("loading eval backend for {}", manifest.eval_key))?;
        Ok(Self::with_backend(Box::new(backend)))
    }

    /// Native path: no artifacts involved. Tensor-core budget from
    /// `REPRO_THREADS` (else serial).
    pub fn native(variant: &VariantCfg) -> Result<Evaluator> {
        Ok(Self::with_backend(Box::new(NativeBackend::new(variant)?)))
    }

    /// [`Evaluator::native`] with an explicit tensor-core thread budget
    /// (serve's native engine and the bench rows land here); precision
    /// still follows `REPRO_PRECISION`.
    pub fn native_with_threads(variant: &VariantCfg, threads: usize) -> Result<Evaluator> {
        Ok(Self::with_backend(Box::new(NativeBackend::with_threads(
            variant, threads,
        )?)))
    }

    /// [`Evaluator::native_with_threads`] with an explicit compute
    /// precision (`repro serve --precision f32` lands here).
    pub fn native_with_opts(
        variant: &VariantCfg,
        threads: usize,
        precision: crate::runtime::native::Precision,
    ) -> Result<Evaluator> {
        Ok(Self::with_backend(Box::new(NativeBackend::with_opts(
            variant, threads, precision,
        )?)))
    }

    pub fn with_backend(backend: Box<dyn Backend>) -> Evaluator {
        let m = backend.manifest();
        let (batch, seq_len, params_end) = (m.batch, m.seq_len, m.params_end);
        Evaluator {
            backend: RefCell::new(backend),
            batch,
            seq_len,
            params_end,
        }
    }

    /// Park a header+params prefix backend-side (device-resident under
    /// PJRT) for repeated scoring/decoding without per-call re-upload.
    pub fn upload_prefix(&self, prefix: &[f32]) -> Result<StateBuf> {
        if prefix.len() != self.params_end {
            return Err(anyhow!(
                "eval prefix length {} != {}",
                prefix.len(),
                self.params_end
            ));
        }
        self.backend.borrow_mut().upload_prefix(prefix)
    }

    /// Score one batch. `tokens` is row-major (batch, seq_len+1); `spans`
    /// is (batch, 2) [start, end). Returns (total_nll, total_count,
    /// per_seq_nll, per_seq_count).
    pub fn score_batch(
        &self,
        prefix: &[f32],
        tokens: &[i32],
        spans: &[i32],
    ) -> Result<(f64, f64, Vec<f32>, Vec<f32>)> {
        let pb = self.upload_prefix(prefix)?;
        self.score_batch_resident(&pb, tokens, spans)
    }

    /// Resident-prefix variant for the serving hot path: the params
    /// prefix stays backend-side (uploaded once per
    /// [`crate::serve::session::ModelSession`]) instead of being
    /// re-uploaded per call as `score_batch` does.
    pub fn score_batch_resident(
        &self,
        prefix: &StateBuf,
        tokens: &[i32],
        spans: &[i32],
    ) -> Result<(f64, f64, Vec<f32>, Vec<f32>)> {
        let b = self.batch;
        let w = self.seq_len + 1;
        anyhow::ensure!(tokens.len() == b * w, "tokens shape");
        anyhow::ensure!(spans.len() == b * 2, "spans shape");
        let v = self.backend.borrow_mut().eval(prefix, tokens, spans)?;
        anyhow::ensure!(v.len() == 2 + 2 * b, "eval output length {}", v.len());
        let nll = v[2..2 + b].to_vec();
        let cnt = v[2 + b..].to_vec();
        Ok((v[0] as f64, v[1] as f64, nll, cnt))
    }

    /// Next-token logits at one position per sequence (the serving
    /// decode step); `tokens` is (batch, seq_len), `pos` is (batch,).
    pub fn logits_resident(
        &self,
        prefix: &StateBuf,
        tokens: &[i32],
        pos: &[i32],
    ) -> Result<Vec<f32>> {
        self.backend.borrow_mut().logits(prefix, tokens, pos)
    }

    /// Whether the decode program is available (old PJRT artifact trees
    /// predate it; native always has it).
    pub fn has_logits(&self) -> bool {
        self.backend.borrow().has_logits()
    }

    // ---- incremental decode (KV cache) ---------------------------------

    /// Prepare a resident prefix for incremental decode (natively: the
    /// f64 model, decoded once per upload and shared across sessions).
    pub fn decode_model(&self, prefix: &StateBuf) -> Result<DecodeModel> {
        self.backend.borrow_mut().decode_model(prefix)
    }

    /// Open a per-request decode session (a K/V cache natively, a token
    /// history under the full-forward fallback).
    pub fn decode_open(&self, model: &DecodeModel) -> Result<DecodeSession> {
        self.backend.borrow_mut().decode_open(model)
    }

    /// Feed the whole prompt once; returns the last position's
    /// next-token logits.
    pub fn decode_prefill(
        &self,
        prefix: &StateBuf,
        model: &DecodeModel,
        st: &mut DecodeSession,
        ids: &[i32],
    ) -> Result<Vec<f32>> {
        self.backend.borrow_mut().decode_prefill(prefix, model, st, ids)
    }

    /// Consume one sampled token; returns the next-token logits.
    pub fn decode_step(
        &self,
        prefix: &StateBuf,
        model: &DecodeModel,
        st: &mut DecodeSession,
        tok: i32,
    ) -> Result<Vec<f32>> {
        self.backend.borrow_mut().decode_step(prefix, model, st, tok)
    }

    /// Retire a session, recycling its buffers where applicable.
    pub fn decode_close(&self, st: DecodeSession) {
        self.backend.borrow_mut().decode_close(st)
    }
}
