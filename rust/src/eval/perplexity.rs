//! Validation perplexity over the held-out split (the paper's headline
//! metric in Table 1).

use anyhow::Result;

use super::Evaluator;
use crate::data::dataset::Dataset;

/// Mean-NLL perplexity over up to `max_batches` sequential val batches.
pub fn perplexity(
    ev: &Evaluator,
    prefix: &[f32],
    ds: &Dataset,
    max_batches: usize,
) -> Result<PplResult> {
    let w = ds.seq_len + 1;
    let full_span: Vec<i32> = (0..ev.batch).flat_map(|_| [0i32, w as i32]).collect();
    let mut total_nll = 0.0;
    let mut total_cnt = 0.0;
    let mut batches = 0;
    // lazy val iteration: each batch is packed into one reusable buffer
    // instead of materializing the whole split's batches up front
    let mut vb = ds.val_batches(ev.batch);
    while batches < max_batches {
        let Some(b) = vb.next_ref() else { break };
        let (nll, cnt, _, _) = ev.score_batch(prefix, b, &full_span)?;
        total_nll += nll;
        total_cnt += cnt;
        batches += 1;
    }
    anyhow::ensure!(batches > 0, "no validation batches");
    let mean_nll = total_nll / total_cnt;
    Ok(PplResult {
        mean_nll,
        ppl: mean_nll.exp(),
        tokens: total_cnt,
        batches,
    })
}

#[derive(Debug, Clone)]
pub struct PplResult {
    pub mean_nll: f64,
    pub ppl: f64,
    pub tokens: f64,
    pub batches: usize,
}
