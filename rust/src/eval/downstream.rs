//! Downstream multiple-choice accuracy (lm-eval-harness `acc_norm`
//! equivalent): every candidate is scored as the length-normalized NLL of
//! its tokens given the context; the lowest-NLL candidate wins.

use anyhow::Result;

use super::Evaluator;
use crate::data::bpe::{Bpe, BOS, PAD};
use crate::data::corpus::Corpus;
use crate::data::tasks::{self, Item, Task};

#[derive(Debug, Clone)]
pub struct TaskResult {
    pub task: String,
    pub accuracy: f64,
    pub n_items: usize,
    pub chance: f64,
}

/// One scoring row: tokens padded to (seq_len+1) and the candidate span.
fn build_row(
    bpe: &Bpe,
    context: &str,
    candidate: &str,
    width: usize,
) -> (Vec<i32>, [i32; 2]) {
    let mut ctx = vec![BOS];
    ctx.extend(bpe.encode(context));
    let cand = bpe.encode(&format!(" {candidate}"));
    // truncate context from the left if needed, always keep the candidate
    let max_ctx = width.saturating_sub(cand.len()).max(1);
    if ctx.len() > max_ctx {
        let cut = ctx.len() - max_ctx;
        ctx.drain(1..1 + cut); // keep BOS
    }
    let cs = ctx.len();
    let mut row = ctx;
    row.extend(&cand);
    row.truncate(width);
    let ce = row.len();
    row.resize(width, PAD);
    // score positions cs-1 .. ce-2 => they predict tokens cs..ce-1
    (row, [(cs as i32) - 1, ce as i32])
}

/// Evaluate one task suite; items are scored in eval-batch groups.
pub fn run_task(
    ev: &Evaluator,
    prefix: &[f32],
    bpe: &Bpe,
    items: &[Item],
    task: Task,
) -> Result<TaskResult> {
    let width = ev.seq_len + 1;
    // flatten all candidate rows
    let mut rows: Vec<(Vec<i32>, [i32; 2])> = Vec::new();
    for it in items {
        for cand in &it.candidates {
            rows.push(build_row(bpe, &it.context, cand, width));
        }
    }
    // score in batches of ev.batch (pad the tail with repeats)
    let mut scores = vec![0f64; rows.len()];
    let mut i = 0;
    while i < rows.len() {
        let mut toks = Vec::with_capacity(ev.batch * width);
        let mut spans = Vec::with_capacity(ev.batch * 2);
        for k in 0..ev.batch {
            let r = &rows[(i + k).min(rows.len() - 1)];
            toks.extend_from_slice(&r.0);
            spans.extend_from_slice(&r.1);
        }
        let (_, _, nll, cnt) = ev.score_batch(prefix, &toks, &spans)?;
        for k in 0..ev.batch {
            if i + k < rows.len() {
                scores[i + k] = nll[k] as f64 / (cnt[k] as f64).max(1.0);
            }
        }
        i += ev.batch;
    }
    // pick argmin per item
    let n_choices = task.n_choices();
    let mut correct = 0usize;
    for (ix, it) in items.iter().enumerate() {
        let s = &scores[ix * n_choices..(ix + 1) * n_choices];
        let best = s
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if best == it.answer {
            correct += 1;
        }
    }
    Ok(TaskResult {
        task: task.name().to_string(),
        accuracy: correct as f64 / items.len() as f64,
        n_items: items.len(),
        chance: 1.0 / n_choices as f64,
    })
}

/// The full suite (hs-syn, piqa-syn, arc-syn) for one model state.
pub fn run_suite(
    ev: &Evaluator,
    prefix: &[f32],
    bpe: &Bpe,
    corpus: &Corpus,
    n_items: usize,
    seed: u64,
) -> Result<Vec<TaskResult>> {
    Task::all()
        .into_iter()
        .map(|task| {
            let items = tasks::generate(task, corpus, n_items, seed);
            run_task(ev, prefix, bpe, &items, task)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::bpe::Bpe;

    #[test]
    fn row_layout_and_spans() {
        let bpe = Bpe::train("some tiny corpus for bpe some tiny corpus", 270);
        let (row, span) = build_row(&bpe, "some tiny", "corpus", 33);
        assert_eq!(row.len(), 33);
        assert_eq!(row[0], BOS);
        assert!(span[0] >= 1 && span[1] > span[0]);
        // decoded candidate region must contain the candidate text
        let region: Vec<i32> = row[(span[0] as usize + 1)..span[1] as usize].to_vec();
        assert!(bpe.decode(&region).contains("corpus"));
        // tail is padding
        assert_eq!(row[32], PAD);
    }

    #[test]
    fn long_context_truncates_left_keeps_candidate() {
        let bpe = Bpe::train("word ".repeat(50).as_str(), 270);
        let long_ctx = "word ".repeat(200);
        let (row, span) = build_row(&bpe, &long_ctx, "tailcand", 33);
        assert_eq!(row.len(), 33);
        assert!(span[1] as usize <= 33);
        let region: Vec<i32> = row[(span[0] as usize + 1)..span[1] as usize].to_vec();
        assert!(bpe.decode(&region).contains("tailcand"));
    }
}
