//! Run-time view of the shared config registry (`configs/*.toml`).
//!
//! Mirrors `python/compile/config.py` — both sides parse the same files,
//! so a variant name is the single source of truth for an experiment's
//! architecture + optimizer.

use std::collections::BTreeMap;

use crate::util::toml::{parse_file, TomlValue};

#[derive(Debug, Clone, PartialEq)]
pub struct ModelCfg {
    pub name: String,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub vocab: usize,
    pub seq_len: usize,
}

impl ModelCfg {
    pub fn ffn(&self) -> usize {
        // 8/3 * hidden rounded to a multiple of 32 (mirror of python)
        round_mult(8.0 / 3.0 * self.hidden as f64, 32)
    }
    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct VariantCfg {
    pub name: String,
    pub model: ModelCfg,
    pub factorize: String,
    pub rank_ratio: f64,
    pub optimizer: String,
    pub batch: usize,
    pub telemetry: bool,
    /// matrix tracked by the spectral telemetry (python default "attn_o")
    pub telemetry_matrix: String,
    /// AdamW lr multiplier for non-matrix tensors under matrix optimizers
    pub emb_lr_mult: f64,
    pub programs: Vec<String>,
}

impl VariantCfg {
    pub fn rank(&self, fan_in: usize) -> usize {
        round_mult(self.rank_ratio * fan_in as f64, 8)
    }
    pub fn eval_key(&self) -> String {
        if self.factorize == "none" {
            format!("eval-{}-dense", self.model.name)
        } else {
            format!(
                "eval-{}-{}-r{}",
                self.model.name,
                self.factorize,
                trim_float(self.rank_ratio)
            )
        }
    }
    /// Tokens consumed per training step.
    pub fn tokens_per_step(&self) -> usize {
        self.batch * self.model.seq_len
    }
}

fn trim_float(x: f64) -> String {
    // match python's `%g`-ish formatting for the eval_key
    let s = format!("{x}");
    s
}

fn round_mult(x: f64, m: usize) -> usize {
    let r = ((x / m as f64).round() as usize) * m;
    r.max(m)
}

pub struct Registry {
    pub models: BTreeMap<String, ModelCfg>,
    pub variants: BTreeMap<String, VariantCfg>,
}

impl Registry {
    pub fn load() -> Result<Registry, String> {
        let models_doc = parse_file(&crate::repo_path("configs/models.toml"))?;
        let mut models = BTreeMap::new();
        for (table, kv) in &models_doc {
            if let Some(name) = table.strip_prefix("model.") {
                models.insert(
                    name.to_string(),
                    ModelCfg {
                        name: name.to_string(),
                        hidden: req_usize(kv, table, "hidden")?,
                        layers: req_usize(kv, table, "layers")?,
                        heads: req_usize(kv, table, "heads")?,
                        vocab: req_usize(kv, table, "vocab")?,
                        seq_len: req_usize(kv, table, "seq_len")?,
                    },
                );
            }
        }

        let var_doc = parse_file(&crate::repo_path("configs/variants.toml"))?;
        let empty = BTreeMap::new();
        let defaults = var_doc.get("defaults").unwrap_or(&empty);
        let d_batch = opt_usize(defaults, "batch").unwrap_or(8);
        let d_ratio = opt_f64(defaults, "rank_ratio").unwrap_or(0.25);
        let d_tel = defaults
            .get("telemetry")
            .and_then(|v| v.as_bool())
            .unwrap_or(true);
        let d_tel_mat = defaults
            .get("telemetry_matrix")
            .and_then(|v| v.as_str())
            .unwrap_or("attn_o")
            .to_string();
        let d_emb_mult = opt_f64(defaults, "emb_lr_mult").unwrap_or(0.3);

        let mut variants = BTreeMap::new();
        for (table, kv) in &var_doc {
            if let Some(name) = table.strip_prefix("variant.") {
                let model_name = kv
                    .get("model")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| format!("{table}: missing model"))?;
                let model = models
                    .get(model_name)
                    .ok_or_else(|| format!("{table}: unknown model '{model_name}'"))?
                    .clone();
                let programs = kv
                    .get("programs")
                    .and_then(|v| v.as_arr())
                    .map(|a| {
                        a.iter()
                            .filter_map(|x| x.as_str().map(str::to_string))
                            .collect()
                    })
                    .unwrap_or_else(|| vec!["init".into(), "step".into(), "eval".into()]);
                variants.insert(
                    name.to_string(),
                    VariantCfg {
                        name: name.to_string(),
                        model,
                        factorize: kv
                            .get("factorize")
                            .and_then(|v| v.as_str())
                            .unwrap_or("all")
                            .to_string(),
                        rank_ratio: opt_f64(kv, "rank_ratio").unwrap_or(d_ratio),
                        optimizer: kv
                            .get("optimizer")
                            .and_then(|v| v.as_str())
                            .ok_or_else(|| format!("{table}: missing optimizer"))?
                            .to_string(),
                        batch: opt_usize(kv, "batch").unwrap_or(d_batch),
                        telemetry: kv
                            .get("telemetry")
                            .and_then(|v| v.as_bool())
                            .unwrap_or(d_tel),
                        telemetry_matrix: kv
                            .get("telemetry_matrix")
                            .and_then(|v| v.as_str())
                            .map(str::to_string)
                            .unwrap_or_else(|| d_tel_mat.clone()),
                        emb_lr_mult: opt_f64(kv, "emb_lr_mult").unwrap_or(d_emb_mult),
                        programs,
                    },
                );
            }
        }
        Ok(Registry { models, variants })
    }

    pub fn variant(&self, name: &str) -> Result<&VariantCfg, String> {
        self.variants
            .get(name)
            .ok_or_else(|| format!("unknown variant '{name}' (see configs/variants.toml)"))
    }
}

fn req_usize(
    kv: &BTreeMap<String, TomlValue>,
    table: &str,
    key: &str,
) -> Result<usize, String> {
    kv.get(key)
        .and_then(|v| v.as_i64())
        .map(|v| v as usize)
        .ok_or_else(|| format!("{table}: missing int '{key}'"))
}

fn opt_usize(kv: &BTreeMap<String, TomlValue>, key: &str) -> Option<usize> {
    kv.get(key).and_then(|v| v.as_i64()).map(|v| v as usize)
}

fn opt_f64(kv: &BTreeMap<String, TomlValue>, key: &str) -> Option<f64> {
    kv.get(key).and_then(|v| v.as_f64())
}

/// One training run's knobs (the values Rust writes into the state header
/// at init — NOT baked into the HLO).
#[derive(Debug, Clone)]
pub struct RunCfg {
    pub total_steps: usize,
    pub base_lr: f64,
    pub weight_decay: f64,
    pub warmup_frac: f64,
    pub seed: u64,
    /// read the state back every N steps (<= loss-ring size 64)
    pub read_interval: usize,
}

impl Default for RunCfg {
    fn default() -> Self {
        RunCfg {
            total_steps: 200,
            base_lr: 0.01,
            weight_decay: 0.01,
            warmup_frac: 0.05,
            seed: 0,
            read_interval: 50,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_loads_and_cross_references() {
        let reg = Registry::load().unwrap();
        assert!(reg.models.contains_key("tiny-s"));
        let v = reg.variant("fact-s-spectron").unwrap();
        assert_eq!(v.model.hidden, 128);
        assert_eq!(v.optimizer, "spectron");
        assert_eq!(v.rank_ratio, 0.25);
        assert_eq!(v.telemetry_matrix, "attn_o");
        assert!((v.emb_lr_mult - 0.3).abs() < 1e-12);
        assert!(v.programs.iter().any(|p| p == "grad"));
        assert!(reg.variant("no-such-variant").is_err());
    }

    #[test]
    fn ffn_and_rank_match_python_rounding() {
        let reg = Registry::load().unwrap();
        let m = &reg.models["tiny-s"];
        assert_eq!(m.ffn(), 352); // 8/3*128 = 341.3 -> 352
        let v = reg.variant("fact-s-spectron").unwrap();
        assert_eq!(v.rank(128), 32);
        assert_eq!(v.rank(352), 88);
    }

    #[test]
    fn eval_keys_dedupe_optimizers() {
        let reg = Registry::load().unwrap();
        let a = reg.variant("fact-s-spectron").unwrap().eval_key();
        let b = reg.variant("fact-s-adamw").unwrap().eval_key();
        let c = reg.variant("dense-s-muon").unwrap().eval_key();
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a, "eval-tiny-s-all-r0.25");
    }
}
