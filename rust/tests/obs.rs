//! Observability-layer integration tests (DESIGN.md §Observability,
//! docs/adr/009-observability-layer.md): exact counters under
//! contention, consistent stats snapshots while writers hammer, the
//! bit-identity contract for traced training, and schema-valid Chrome
//! trace export.
//!
//! The trace sink is process-global, so exactly one test here
//! (`observed_training_is_bit_identical`) installs it; everything else
//! uses private registries or plain files.

use std::sync::Arc;

use spectron::config::{Registry, RunCfg};
use spectron::data::bpe::Bpe;
use spectron::data::corpus::{Corpus, CorpusCfg};
use spectron::data::dataset::{Dataset, Split};
use spectron::monitor::{Monitor, MonitorCfg};
use spectron::obs;
use spectron::runtime::{NativeBackend, Precision};
use spectron::serve::{RouteStats, ServeStats};
use spectron::train::Trainer;
use spectron::util::json::Json;

/// Writers on N threads against one shared counter family plus one
/// histogram, with renders interleaved mid-flight. After joining, the
/// totals are exact — no event lost, none double-counted.
#[test]
fn concurrent_counters_are_exact_under_contention() {
    const THREADS: usize = 8;
    const PER_THREAD: usize = 10_000;
    let reg = Arc::new(obs::Registry::new());
    let c = reg.counter("hammer_total", &[]);
    let h = reg.histogram("hammer_ms", &[], &[1.0, 10.0, 100.0]);

    let workers: Vec<_> = (0..THREADS)
        .map(|t| {
            let (c, h) = (c.clone(), h.clone());
            std::thread::spawn(move || {
                for i in 0..PER_THREAD {
                    c.inc();
                    h.observe(((t * PER_THREAD + i) % 200) as f64);
                }
            })
        })
        .collect();
    // snapshots taken while writers run must stay parseable; exactness
    // is only asserted after the join
    for _ in 0..20 {
        let text = reg.render();
        obs::expo::parse_prometheus(&text).expect("mid-flight render parses");
    }
    for w in workers {
        w.join().unwrap();
    }

    let total = (THREADS * PER_THREAD) as u64;
    assert_eq!(c.get(), total);
    assert_eq!(h.count(), total);
    let text = reg.render();
    assert!(text.contains(&format!("hammer_total {total}")), "{text}");
    assert!(
        text.contains(&format!("hammer_ms_bucket{{le=\"+Inf\"}} {total}")),
        "{text}"
    );
}

/// Serve and route stats stay internally consistent while N threads
/// record: every mid-flight snapshot parses and never exceeds the final
/// totals, and the post-join totals are exact.
#[test]
fn stats_snapshots_are_consistent_under_concurrency() {
    const THREADS: usize = 8;
    const PER_THREAD: usize = 2_000;
    let stats = Arc::new(ServeStats::new());
    let workers: Vec<_> = (0..THREADS)
        .map(|t| {
            let stats = stats.clone();
            std::thread::spawn(move || {
                for i in 0..PER_THREAD {
                    stats.record_request((i % 50) as f64, i % 10 != 0, 2, 3);
                    if i % 100 == 0 {
                        stats.record_batch("v", "score", 4, 0.5, 1.0, 2.0 + t as f64);
                    }
                }
            })
        })
        .collect();
    let total = (THREADS * PER_THREAD) as f64;
    for _ in 0..50 {
        let j = stats.snapshot();
        let seen = j.get("requests").unwrap().as_f64().unwrap();
        assert!(seen <= total, "snapshot overshot: {seen} > {total}");
        let errors = j.get("errors").unwrap().as_f64().unwrap();
        assert!(errors <= seen, "more errors than requests: {errors} > {seen}");
    }
    for w in workers {
        w.join().unwrap();
    }
    let j = stats.snapshot();
    assert_eq!(j.get("requests").unwrap().as_f64(), Some(total));
    assert_eq!(j.get("errors").unwrap().as_f64(), Some(total / 10.0));
    assert_eq!(j.get("tokens_out").unwrap().as_f64(), Some(total * 3.0));
    assert_eq!(
        j.get("batches").unwrap().as_f64(),
        Some((THREADS * PER_THREAD / 100) as f64)
    );

    let route = Arc::new(RouteStats::new(2));
    let workers: Vec<_> = (0..THREADS)
        .map(|t| {
            let route = route.clone();
            std::thread::spawn(move || {
                for i in 0..PER_THREAD {
                    route.record_forward(t % 2);
                    route.record_done((i % 30) as f64, i % 7 != 0);
                }
            })
        })
        .collect();
    for w in workers {
        w.join().unwrap();
    }
    let j = route.snapshot();
    assert_eq!(j.get("requests").unwrap().as_f64(), Some(total));
    let Some(Json::Arr(per)) = j.get("forwards_per_replica") else {
        panic!("forwards_per_replica missing")
    };
    let forwards: f64 = per.iter().filter_map(|v| v.as_f64()).sum();
    assert_eq!(forwards, total);
}

/// The ADR-005 invariant extends to tracing (docs/adr/009): a traced
/// native train run is bit-identical to an untraced one, at every
/// thread count and both compute precisions — spans time phase
/// boundaries and never touch batch or state data.
#[test]
fn observed_training_is_bit_identical() {
    let reg = Registry::load().unwrap();
    let v = reg.variant("fact-z0-spectron").unwrap();
    let corpus = Corpus::new(CorpusCfg::default());
    let bpe = Bpe::train(&corpus.text_range(1, 150), v.model.vocab);
    let ds = Arc::new(Dataset::build_with(&corpus, &bpe, 800, 128));
    let run = RunCfg {
        total_steps: 8,
        base_lr: 0.01,
        weight_decay: 0.01,
        warmup_frac: 0.05,
        seed: 0,
        read_interval: 4,
    };

    for precision in [Precision::F64, Precision::F32] {
        for threads in [1usize, 4] {
            let run_once = |traced: bool| -> Vec<f32> {
                let be = NativeBackend::with_opts(v, threads, precision).unwrap();
                let mut t = Trainer::with_backend(Box::new(be), v, run.clone()).unwrap();
                let mut batches = ds.batches(Split::Train, v.batch, 0);
                if traced {
                    obs::trace::install_memory();
                }
                let res = t.train(&mut batches, 8).unwrap();
                if traced {
                    let rows = obs::trace::drain_memory();
                    obs::trace::uninstall();
                    assert!(
                        rows.iter().any(|r| {
                            r.get("name").and_then(Json::as_str) == Some("forward")
                        }),
                        "traced run recorded no forward span: {rows:?}"
                    );
                }
                assert_eq!(res.steps_done, 8);
                t.state_vec().unwrap()
            };
            let untraced = run_once(false);
            let traced = run_once(true);
            assert_eq!(untraced.len(), traced.len());
            for (i, (a, b)) in untraced.iter().zip(&traced).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{precision:?} threads={threads}: state diverged at slot {i}"
                );
            }
        }
    }
}

/// `repro trace-export`'s conversion path: a recorded JSONL log (with a
/// torn final line, as a killed run leaves) converts to Chrome
/// trace-event JSON that passes the schema check; mid-file corruption
/// stays a hard error.
#[test]
fn chrome_export_from_jsonl_is_schema_valid() {
    let dir = std::env::temp_dir().join(format!("spectron-obs-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.jsonl");
    std::fs::write(
        &path,
        "{\"name\":\"forward\",\"cat\":\"train\",\"ts_us\":10,\"dur_us\":250,\"tid\":1}\n\
         {\"name\":\"serve_request\",\"cat\":\"serve\",\"ts_us\":400,\"dur_us\":90,\
          \"tid\":2,\"trace\":\"req-1\",\"args\":{\"tokens_out\":5}}\n\
         {\"name\":\"torn tail, killed mid-wri",
    )
    .unwrap();
    let doc = obs::expo::chrome_from_jsonl(&path).unwrap();
    obs::expo::validate_chrome(&doc).expect("exported doc satisfies the schema");
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    assert_eq!(events.len(), 2, "torn tail dropped, valid rows kept");
    assert_eq!(
        events[1].get("args").unwrap().get("trace").and_then(Json::as_str),
        Some("req-1")
    );

    let bad = dir.join("bad.jsonl");
    std::fs::write(&bad, "not json\n{\"name\":\"x\",\"ts_us\":0,\"dur_us\":1}\n").unwrap();
    assert!(
        obs::expo::chrome_from_jsonl(&bad).is_err(),
        "mid-file corruption must be fatal, not skipped"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// The `metrics` wire op's payload: after train/serve/route/monitor
/// activity in one process, the global registry renders Prometheus text
/// that parses and names families from every subsystem.
#[test]
fn global_render_covers_every_subsystem() {
    obs::global().counter("train_steps_total", &[]).inc();
    let serve = ServeStats::new();
    serve.record_request(3.0, true, 2, 5);
    let route = RouteStats::new(1);
    route.record_forward(0);
    route.record_done(4.0, true);
    let _monitor = Monitor::new(MonitorCfg::default()); // registers its families

    let text = obs::global().render();
    let samples = obs::expo::parse_prometheus(&text).expect("exposition parses");
    for family in [
        "train_steps_total",
        "serve_requests_total",
        "serve_request_latency_ms_count",
        "route_requests_total",
        "route_forwards_total{replica=\"0\"}",
        "monitor_events_total",
    ] {
        assert!(
            samples.iter().any(|(name, _)| name == family),
            "{family} missing from exposition:\n{text}"
        );
    }
    assert!(text.contains("# TYPE serve_request_latency_ms histogram"), "{text}");
}
