//! End-to-end serve tests: a fake NDJSON client over a localhost socket
//! against a server running the mock engine (no artifacts required), plus
//! a PJRT-backed smoke test that only runs when artifacts are built.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use spectron::serve::{BatchEngine, MockEngine, ServeCfg, Server, ServerHandle};
use spectron::util::json::Json;

/// A line-oriented test client with a read timeout so a server bug fails
/// the test instead of hanging it.
struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect");
        stream
            .set_read_timeout(Some(Duration::from_secs(20)))
            .expect("read timeout");
        Client {
            reader: BufReader::new(stream.try_clone().expect("clone")),
            writer: stream,
        }
    }

    fn send(&mut self, line: &str) {
        writeln!(self.writer, "{line}").expect("send");
        self.writer.flush().expect("flush");
    }

    fn recv(&mut self) -> Json {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).expect("recv");
        assert!(n > 0, "server closed the connection unexpectedly");
        Json::parse(line.trim()).expect("response is json")
    }

    fn roundtrip(&mut self, line: &str) -> Json {
        self.send(line);
        self.recv()
    }
}

fn mock_server(
    max_batch: usize,
    max_wait: Duration,
) -> (ServerHandle, Arc<Mutex<Vec<usize>>>) {
    let seen = Arc::new(Mutex::new(Vec::new()));
    let cfg = ServeCfg {
        addr: "127.0.0.1:0".into(), // ephemeral port: tests never collide
        max_batch,
        max_wait,
        workers: 1,
        default_variant: Some("mock".into()),
        metrics_name: None,
        idle_timeout: None,
        queue_cap: 1024,
    };
    let handle = Server::spawn(cfg, MockEngine::factory(Duration::ZERO, seen.clone()))
        .expect("spawn server");
    (handle, seen)
}

#[test]
fn roundtrip_generate_score_and_errors() {
    let (handle, _) = mock_server(4, Duration::from_millis(5));
    let mut c = Client::connect(handle.addr);

    let r = c.roundtrip(r#"{"id":1,"op":"generate","prompt":"a b c","max_tokens":5}"#);
    assert_eq!(r.get("id").unwrap().as_usize(), Some(1));
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
    assert_eq!(r.get("text").unwrap().as_str(), Some("a b c a b"));
    assert_eq!(r.get("tokens_out").unwrap().as_usize(), Some(5));
    assert!(r.get("latency_ms").unwrap().as_f64().unwrap() >= 0.0);

    let r = c.roundtrip(r#"{"id":2,"op":"score","text":"one two three"}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
    assert_eq!(r.get("nll").unwrap().as_f64(), Some(3.0));
    assert_eq!(r.get("tokens").unwrap().as_f64(), Some(3.0));

    // malformed line: error response, connection stays usable
    let r = c.roundtrip("this is not json");
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    let r = c.roundtrip(r#"{"id":3,"op":"fly"}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    assert!(r.get("error").unwrap().as_str().unwrap().contains("unknown op"));

    let r = c.roundtrip(r#"{"id":4,"op":"score","text":"still works"}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)));

    handle.shutdown();
}

#[test]
fn pipelined_requests_coalesce_into_batches() {
    // generous deadline so the flush trigger must be the full batch
    let (handle, seen) = mock_server(4, Duration::from_millis(500));
    let mut c = Client::connect(handle.addr);

    for i in 0..8 {
        c.send(&format!(r#"{{"id":{i},"op":"score","text":"w{i}"}}"#));
    }
    let mut got = HashMap::new();
    for _ in 0..8 {
        let r = c.recv();
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        got.insert(
            r.get("id").unwrap().as_usize().unwrap(),
            r.get("batch").unwrap().as_usize().unwrap(),
        );
    }
    assert_eq!(got.len(), 8, "every id answered exactly once");
    let batches = seen.lock().unwrap().clone();
    assert_eq!(batches.iter().sum::<usize>(), 8);
    assert!(
        batches.iter().any(|&b| b == 4),
        "expected at least one full batch, saw {batches:?}"
    );

    handle.shutdown();
}

#[test]
fn lone_request_is_flushed_by_the_deadline() {
    let (handle, seen) = mock_server(8, Duration::from_millis(20));
    let mut c = Client::connect(handle.addr);
    let t0 = std::time::Instant::now();
    let r = c.roundtrip(r#"{"id":1,"op":"score","text":"solo"}"#);
    let elapsed = t0.elapsed();
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
    assert_eq!(r.get("batch").unwrap().as_usize(), Some(1));
    assert!(
        elapsed < Duration::from_secs(5),
        "deadline flush too slow: {elapsed:?}"
    );
    assert_eq!(*seen.lock().unwrap(), vec![1]);
    handle.shutdown();
}

#[test]
fn concurrent_connections_share_batches() {
    let (handle, seen) = mock_server(4, Duration::from_millis(100));
    let addr = handle.addr;
    let threads: Vec<_> = (0..4)
        .map(|i| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr);
                let r = c.roundtrip(&format!(
                    r#"{{"id":{i},"op":"generate","prompt":"client {i}","max_tokens":3}}"#
                ));
                assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
                r.get("batch").unwrap().as_usize().unwrap()
            })
        })
        .collect();
    let sizes: Vec<usize> = threads.into_iter().map(|t| t.join().unwrap()).collect();
    assert_eq!(sizes.len(), 4);
    let batches = seen.lock().unwrap().clone();
    assert_eq!(batches.iter().sum::<usize>(), 4);
    assert!(
        batches.len() < 4 || sizes.iter().any(|&s| s > 1),
        "four concurrent requests should share at least one batch: {batches:?}"
    );
    handle.shutdown();
}

#[test]
fn metrics_op_returns_parseable_prometheus_text() {
    let (handle, _) = mock_server(4, Duration::from_millis(5));
    let mut c = Client::connect(handle.addr);
    for i in 0..3 {
        c.roundtrip(&format!(r#"{{"id":{i},"op":"score","text":"x"}}"#));
    }
    let r = c.roundtrip(r#"{"id":9,"op":"metrics"}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
    let text = r.get("metrics").unwrap().as_str().expect("metrics is text");
    let samples = spectron::obs::expo::parse_prometheus(text).expect("exposition parses");
    // the process-global registry accumulates across tests in this
    // binary, so assert presence and a floor, never exact counts
    let req = samples
        .iter()
        .find(|(name, _)| name == "serve_requests_total")
        .expect("serve_requests_total present");
    assert!(req.1 >= 3.0, "expected at least this test's requests, got {}", req.1);
    assert!(
        samples.iter().any(|(n, _)| n.starts_with("serve_request_latency_ms_bucket")),
        "latency histogram missing"
    );
    handle.shutdown();
}

#[test]
fn stats_and_wire_shutdown() {
    let (handle, _) = mock_server(4, Duration::from_millis(5));
    let mut c = Client::connect(handle.addr);
    for i in 0..3 {
        c.roundtrip(&format!(r#"{{"id":{i},"op":"score","text":"x"}}"#));
    }
    let r = c.roundtrip(r#"{"id":9,"op":"stats"}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
    let stats = r.get("stats").unwrap();
    assert_eq!(stats.get("requests").unwrap().as_usize(), Some(3));
    assert_eq!(stats.get("errors").unwrap().as_usize(), Some(0));
    assert!(stats.get("latency_p50_ms").unwrap().as_f64().unwrap() >= 0.0);
    assert!(stats.get("batch_occupancy_mean").unwrap().as_f64().unwrap() > 0.0);

    // graceful stop over the wire: handle.wait() must return
    let r = c.roundtrip(r#"{"id":10,"op":"shutdown"}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
    let final_stats = handle.wait();
    assert_eq!(final_stats.get("requests").unwrap().as_usize(), Some(3));
}

#[test]
fn engine_init_failure_answers_instead_of_hanging() {
    let cfg = ServeCfg {
        addr: "127.0.0.1:0".into(),
        max_batch: 2,
        max_wait: Duration::from_millis(5),
        workers: 1,
        default_variant: Some("mock".into()),
        metrics_name: None,
        idle_timeout: None,
        queue_cap: 1024,
    };
    let factory: spectron::serve::EngineFactory =
        Arc::new(|| anyhow::bail!("no engine for you"));
    let handle = Server::spawn(cfg, factory).expect("spawn");
    let mut c = Client::connect(handle.addr);
    let r = c.roundtrip(r#"{"id":1,"op":"score","text":"x"}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    assert!(r.get("error").unwrap().as_str().unwrap().contains("engine init failed"));
    handle.shutdown();
}

/// Real-engine smoke test; runs only with built artifacts (same gating
/// as the train-loop integration suite).
#[test]
fn pjrt_engine_scores_over_the_wire() {
    use spectron::config::{Registry, RunCfg};
    use spectron::runtime::{ArtifactIndex, Runtime};
    use spectron::train::{checkpoint, Trainer};

    let root = ArtifactIndex::default_root();
    if !root.join("index.json").exists() {
        eprintln!("skipping serve PJRT test: run `make artifacts` first");
        return;
    }
    let idx = ArtifactIndex::load(&root).unwrap();
    let reg = Registry::load().unwrap();
    let rt = Runtime::shared().unwrap();
    let variant = "fact-z0-spectron";
    let v = reg.variant(variant).unwrap();

    // a fresh init state is a perfectly valid (if untrained) checkpoint
    let mut trainer = Trainer::new(&rt, &idx, v, RunCfg::default()).unwrap();
    let ckpt = std::env::temp_dir().join(format!(
        "spectron-serve-test-{}.ckpt",
        std::process::id()
    ));
    checkpoint::save(&ckpt, variant, &trainer.state_vec().unwrap()).unwrap();

    let corpus = spectron::data::corpus::Corpus::new(Default::default());
    let bpe = Arc::new(spectron::data::bpe::Bpe::train(
        &corpus.text_range(1, 60),
        v.model.vocab,
    ));
    let mut ckpts = std::collections::BTreeMap::new();
    ckpts.insert(variant.to_string(), ckpt.clone());
    let factory: spectron::serve::EngineFactory = {
        let idx = idx.clone();
        Arc::new(move || {
            Ok(Box::new(
                spectron::serve::PjrtEngine::new(idx.clone(), bpe.clone(), ckpts.clone(), 2)?,
            ) as Box<dyn BatchEngine>)
        })
    };
    let cfg = ServeCfg {
        addr: "127.0.0.1:0".into(),
        max_batch: 4,
        max_wait: Duration::from_millis(10),
        workers: 1,
        default_variant: Some(variant.to_string()),
        metrics_name: None,
        idle_timeout: None,
        queue_cap: 1024,
    };
    let handle = Server::spawn(cfg, factory).expect("spawn");
    let mut c = Client::connect(handle.addr);

    let r = c.roundtrip(r#"{"id":1,"op":"score","text":"the cat sat on the mat"}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    let nll = r.get("nll").unwrap().as_f64().unwrap();
    let tokens = r.get("tokens").unwrap().as_f64().unwrap();
    assert!(tokens >= 1.0);
    // an untrained model scores near uniform: nll/token ~ ln(vocab)
    let per_token = nll / tokens;
    assert!(
        per_token > 2.0 && per_token < (v.model.vocab as f64).ln() + 2.0,
        "per-token nll {per_token}"
    );

    // generate needs the logits program; older artifact trees lack it,
    // in which case the server must answer with a clean error
    let r = c.roundtrip(r#"{"id":2,"op":"generate","prompt":"the cat","max_tokens":4}"#);
    if r.get("ok") == Some(&Json::Bool(true)) {
        assert!(r.get("tokens_out").unwrap().as_usize().unwrap() <= 4);
    } else {
        assert!(r.get("error").unwrap().as_str().unwrap().contains("decode program"));
    }

    c.roundtrip(r#"{"id":3,"op":"shutdown"}"#);
    handle.wait();
    std::fs::remove_file(&ckpt).ok();
}

/// Real-model serving with NO artifacts: the native engine loads a real
/// checkpoint, scores and generates over the wire — the artifact-free
/// deployment scenario of DESIGN.md §Backends. Runs unconditionally.
#[test]
fn native_engine_serves_over_the_wire() {
    use spectron::config::{Registry, RunCfg};
    use spectron::train::{checkpoint, Trainer};

    let reg = Registry::load().unwrap();
    let variant = "fact-z0-spectron";
    let v = reg.variant(variant).unwrap();

    // a fresh native init state is a valid (untrained) checkpoint
    let mut trainer = Trainer::native(v, RunCfg::default()).unwrap();
    let ckpt = std::env::temp_dir().join(format!(
        "spectron-serve-native-{}.ckpt",
        std::process::id()
    ));
    checkpoint::save(&ckpt, variant, &trainer.state_vec().unwrap()).unwrap();

    let corpus = spectron::data::corpus::Corpus::new(Default::default());
    let bpe = Arc::new(spectron::data::bpe::Bpe::train(
        &corpus.text_range(1, 60),
        v.model.vocab,
    ));
    let mut ckpts = std::collections::BTreeMap::new();
    ckpts.insert(variant.to_string(), ckpt.clone());
    let factory: spectron::serve::EngineFactory = {
        Arc::new(move || {
            Ok(Box::new(spectron::serve::NativeEngine::new(
                bpe.clone(),
                ckpts.clone(),
                2,
            )?) as Box<dyn BatchEngine>)
        })
    };
    let cfg = ServeCfg {
        addr: "127.0.0.1:0".into(),
        max_batch: 4,
        max_wait: Duration::from_millis(10),
        workers: 1,
        default_variant: Some(variant.to_string()),
        metrics_name: None,
        idle_timeout: None,
        queue_cap: 1024,
    };
    let handle = Server::spawn(cfg, factory).expect("spawn");
    let mut c = Client::connect(handle.addr);

    let r = c.roundtrip(r#"{"id":1,"op":"score","text":"the cat sat on the mat"}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    let nll = r.get("nll").unwrap().as_f64().unwrap();
    let tokens = r.get("tokens").unwrap().as_f64().unwrap();
    assert!(tokens >= 1.0);
    // an untrained model scores near uniform: nll/token ~ ln(vocab)
    let per_token = nll / tokens;
    assert!(
        per_token > 2.0 && per_token < (v.model.vocab as f64).ln() + 2.0,
        "per-token nll {per_token}"
    );

    // the native backend always has the decode program
    let r = c.roundtrip(r#"{"id":2,"op":"generate","prompt":"the cat","max_tokens":4}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    assert!(r.get("tokens_out").unwrap().as_usize().unwrap() <= 4);

    c.roundtrip(r#"{"id":3,"op":"shutdown"}"#);
    handle.wait();
    std::fs::remove_file(&ckpt).ok();
}

/// Build a native-engine server over a fresh init checkpoint with the
/// given decode-slot count (0 = lockstep baseline). Returns the handle
/// plus the checkpoint path for cleanup.
fn native_server(slots: usize, tag: &str) -> (ServerHandle, std::path::PathBuf) {
    use spectron::config::{Registry, RunCfg};
    use spectron::train::{checkpoint, Trainer};

    let reg = Registry::load().unwrap();
    let variant = "fact-z0-spectron";
    let v = reg.variant(variant).unwrap();
    let mut trainer = Trainer::native(v, RunCfg::default()).unwrap();
    let ckpt = std::env::temp_dir().join(format!(
        "spectron-serve-cb-{tag}-{}.ckpt",
        std::process::id()
    ));
    checkpoint::save(&ckpt, variant, &trainer.state_vec().unwrap()).unwrap();

    let corpus = spectron::data::corpus::Corpus::new(Default::default());
    let bpe = Arc::new(spectron::data::bpe::Bpe::train(
        &corpus.text_range(1, 60),
        v.model.vocab,
    ));
    let mut ckpts = std::collections::BTreeMap::new();
    ckpts.insert(variant.to_string(), ckpt.clone());
    let factory: spectron::serve::EngineFactory = Arc::new(move || {
        Ok(Box::new(spectron::serve::NativeEngine::with_opts(
            bpe.clone(),
            ckpts.clone(),
            2,
            1,
            slots,
        )?) as Box<dyn BatchEngine>)
    });
    let cfg = ServeCfg {
        addr: "127.0.0.1:0".into(),
        max_batch: 4,
        max_wait: Duration::from_millis(5),
        workers: 1,
        default_variant: Some(variant.to_string()),
        metrics_name: None,
        idle_timeout: None,
        queue_cap: 1024,
    };
    (Server::spawn(cfg, factory).expect("spawn"), ckpt)
}

fn gen_req(id: usize, prompt: &str, max_tokens: usize, seed: u64) -> String {
    format!(
        r#"{{"id":{id},"op":"generate","prompt":"{prompt}","max_tokens":{max_tokens},"temperature":0.9,"seed":{seed}}}"#
    )
}

/// Continuous batching over the wire: concurrent sessions produce the
/// same transcripts as solo runs AND as the lockstep (slots = 0)
/// baseline — the KV cache changes scheduling, never output — and short
/// requests retire before a long batchmate finishes decoding.
#[test]
fn continuous_batching_join_leave() {
    let (handle, ckpt) = native_server(4, "slots");
    let (lockstep, ckpt2) = native_server(0, "lockstep");
    let mut c = Client::connect(handle.addr);

    // pick a long-request seed whose solo transcript is comfortably long
    // (an untrained model is near-uniform, so BOS-stops are ~0.1%/step;
    // the retry loop makes the test robust to the unlucky ones)
    let prompt = "the cat sat on";
    let mut long_seed = None;
    for seed in [5u64, 11, 17, 23] {
        let r = c.roundtrip(&gen_req(0, prompt, 64, seed));
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        if r.get("tokens_out").unwrap().as_usize().unwrap() >= 8 {
            long_seed = Some(seed);
            break;
        }
    }
    let long_seed = long_seed.expect("some seed decodes >= 8 tokens");

    // solo transcripts on the continuous-batching server, one at a time
    let reqs = [
        gen_req(1, prompt, 64, long_seed),
        gen_req(2, "a b c", 1, 6),
        gen_req(3, "one two", 2, 7),
    ];
    let mut solo = HashMap::new();
    for req in &reqs {
        let r = c.roundtrip(req);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        solo.insert(
            r.get("id").unwrap().as_usize().unwrap(),
            r.get("text").unwrap().as_str().unwrap().to_string(),
        );
    }

    // the lockstep full-forward baseline must produce the same text:
    // cached logits are bit-identical, so sampling walks the same path
    let mut lc = Client::connect(lockstep.addr);
    for req in &reqs {
        let r = lc.roundtrip(req);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        let id = r.get("id").unwrap().as_usize().unwrap();
        assert_eq!(
            r.get("text").unwrap().as_str().unwrap(),
            solo[&id],
            "lockstep transcript diverged for id {id}"
        );
    }
    lockstep.shutdown();
    std::fs::remove_file(&ckpt2).ok();

    // concurrent phase: pipeline all three; the short sessions join while
    // the long one decodes and must retire first
    for req in &reqs {
        c.send(req);
    }
    let mut arrival = Vec::new();
    for _ in 0..3 {
        let r = c.recv();
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        let id = r.get("id").unwrap().as_usize().unwrap();
        assert_eq!(
            r.get("text").unwrap().as_str().unwrap(),
            solo[&id],
            "concurrent transcript diverged for id {id}"
        );
        arrival.push(id);
    }
    assert_eq!(
        arrival[2], 1,
        "short requests must finish while the long one still decodes; \
         arrival order {arrival:?}"
    );

    // drained server leaks no slots; sessions really joined the table
    let r = c.roundtrip(r#"{"id":9,"op":"stats"}"#);
    let stats = r.get("stats").unwrap();
    assert_eq!(stats.get("slots_active").unwrap().as_usize(), Some(0));
    assert!(stats.get("slot_joins").unwrap().as_usize().unwrap() >= 7);
    assert!(stats.get("prefill_tokens").unwrap().as_usize().unwrap() > 0);
    handle.shutdown();
    std::fs::remove_file(&ckpt).ok();
}

/// A client that vanishes mid-decode must free its slot for the next
/// request instead of decoding to a dead socket forever.
#[test]
fn disconnect_mid_decode_frees_slot() {
    let seen = Arc::new(Mutex::new(Vec::new()));
    let cfg = ServeCfg {
        addr: "127.0.0.1:0".into(),
        max_batch: 4,
        max_wait: Duration::from_millis(2),
        workers: 1,
        default_variant: Some("mock".into()),
        metrics_name: None,
        idle_timeout: None,
        queue_cap: 1024,
    };
    // ONE slot, 20ms per decode step: the doomed request would take ~2s
    let factory =
        MockEngine::factory_streaming(Duration::from_millis(20), 1, seen.clone());
    let handle = Server::spawn(cfg, factory).expect("spawn");

    let mut a = Client::connect(handle.addr);
    a.send(r#"{"id":1,"op":"generate","prompt":"doomed request","max_tokens":100}"#);
    // let it get admitted and decode a few steps, then vanish
    std::thread::sleep(Duration::from_millis(120));
    drop(a);

    let mut b = Client::connect(handle.addr);
    let t0 = std::time::Instant::now();
    let r = b.roundtrip(r#"{"id":2,"op":"generate","prompt":"quick one","max_tokens":2}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    assert_eq!(r.get("text").unwrap().as_str(), Some("quick one"));
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "freed slot should admit the next request promptly"
    );

    let r = b.roundtrip(r#"{"id":3,"op":"stats"}"#);
    let stats = r.get("stats").unwrap();
    assert_eq!(
        stats.get("slot_disconnect_frees").unwrap().as_usize(),
        Some(1),
        "{stats}"
    );
    assert_eq!(stats.get("slots_active").unwrap().as_usize(), Some(0));
    handle.shutdown();
}

/// Admission control: a full queue sheds load with an `overloaded` error
/// instead of queueing without bound (or hanging the client).
#[test]
fn queue_full_returns_overloaded() {
    let seen = Arc::new(Mutex::new(Vec::new()));
    let cfg = ServeCfg {
        addr: "127.0.0.1:0".into(),
        max_batch: 1,
        max_wait: Duration::from_millis(1),
        workers: 1,
        default_variant: Some("mock".into()),
        metrics_name: None,
        idle_timeout: None,
        queue_cap: 2,
    };
    let factory = MockEngine::factory(Duration::from_millis(50), seen.clone());
    let handle = Server::spawn(cfg, factory).expect("spawn");
    let mut c = Client::connect(handle.addr);

    for i in 0..10 {
        c.send(&format!(r#"{{"id":{i},"op":"score","text":"w{i}"}}"#));
    }
    let mut served = 0;
    let mut shed = 0;
    for _ in 0..10 {
        let r = c.recv(); // read timeout turns a hang into a failure
        if r.get("ok") == Some(&Json::Bool(true)) {
            served += 1;
        } else {
            assert_eq!(r.get("error").unwrap().as_str(), Some("overloaded"), "{r}");
            shed += 1;
        }
    }
    assert_eq!(served + shed, 10, "every request answered exactly once");
    assert!(served >= 1, "the worker should serve at least the first request");
    assert!(shed >= 1, "a 10-deep burst over a 2-deep queue must shed load");

    let r = c.roundtrip(r#"{"id":99,"op":"stats"}"#);
    let stats = r.get("stats").unwrap();
    assert_eq!(stats.get("overloaded").unwrap().as_usize(), Some(shed));
    handle.shutdown();
}

#[test]
fn overloaded_shed_carries_a_retry_after_hint() {
    let seen = Arc::new(Mutex::new(Vec::new()));
    let cfg = ServeCfg {
        addr: "127.0.0.1:0".into(),
        max_batch: 1,
        max_wait: Duration::from_millis(20),
        workers: 1,
        default_variant: Some("mock".into()),
        metrics_name: None,
        idle_timeout: None,
        queue_cap: 2,
    };
    let factory = MockEngine::factory(Duration::from_millis(50), seen.clone());
    let handle = Server::spawn(cfg, factory).expect("spawn");
    let mut c = Client::connect(handle.addr);

    for i in 0..10 {
        c.send(&format!(r#"{{"id":{i},"op":"score","text":"w{i}"}}"#));
    }
    let mut hints = 0;
    for _ in 0..10 {
        let r = c.recv();
        if r.get("ok") == Some(&Json::Bool(false)) {
            assert_eq!(r.get("error").unwrap().as_str(), Some("overloaded"), "{r}");
            let ms = r
                .get("retry_after_ms")
                .expect("overloaded shed carries retry_after_ms")
                .as_f64()
                .unwrap();
            // clamped band from server::retry_after_hint, scaled by depth
            assert!((10.0..=2000.0).contains(&ms), "hint {ms} out of band");
            hints += 1;
        }
    }
    assert!(hints >= 1, "burst must shed at least one request");
    handle.shutdown();
}

#[test]
fn idle_timeout_reaps_silent_connections_but_not_active_ones() {
    let seen = Arc::new(Mutex::new(Vec::new()));
    let cfg = ServeCfg {
        addr: "127.0.0.1:0".into(),
        max_batch: 4,
        max_wait: Duration::from_millis(5),
        workers: 1,
        default_variant: Some("mock".into()),
        metrics_name: None,
        idle_timeout: Some(Duration::from_millis(100)),
        queue_cap: 1024,
    };
    let handle = Server::spawn(cfg, MockEngine::factory(Duration::ZERO, seen))
        .expect("spawn");

    // an active client keeps working across several idle windows as
    // long as each gap stays under the timeout
    let mut active = Client::connect(handle.addr);
    for i in 0..3 {
        let r = active.roundtrip(&format!(r#"{{"id":{i},"op":"score","text":"x"}}"#));
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        std::thread::sleep(Duration::from_millis(40));
    }

    // a silent client that owes no replies is dropped: read sees EOF
    let silent = TcpStream::connect(handle.addr).expect("connect");
    silent
        .set_read_timeout(Some(Duration::from_secs(10)))
        .expect("read timeout");
    let mut buf = String::new();
    let n = BufReader::new(silent).read_line(&mut buf).expect("idle read");
    assert_eq!(n, 0, "silent idle connection should be closed, got {buf:?}");

    // the active client's connection survived the whole time
    let r = active.roundtrip(r#"{"id":9,"op":"score","text":"still here"}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    handle.shutdown();
}

#[test]
fn drain_and_resume_cycle_over_the_wire() {
    let (handle, _) = mock_server(4, Duration::from_millis(5));
    let mut c = Client::connect(handle.addr);

    // ping reports not draining
    let r = c.roundtrip(r#"{"id":1,"op":"ping"}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    assert_eq!(r.get("pong"), Some(&Json::Bool(true)));
    assert_eq!(r.get("draining"), Some(&Json::Bool(false)));

    // drain: quiesces (nothing in flight) and flips the flag
    let r = c.roundtrip(r#"{"id":2,"op":"drain"}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    assert_eq!(r.get("drained"), Some(&Json::Bool(true)));
    assert_eq!(r.get("inflight").unwrap().as_usize(), Some(0));

    // while draining: model ops shed with the retryable "draining"
    // error, control ops still answer
    let r = c.roundtrip(r#"{"id":3,"op":"score","text":"x"}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    assert_eq!(r.get("error").unwrap().as_str(), Some("draining"), "{r}");
    let r = c.roundtrip(r#"{"id":4,"op":"ping"}"#);
    assert_eq!(r.get("draining"), Some(&Json::Bool(true)));

    // resume: admitting again
    let r = c.roundtrip(r#"{"id":5,"op":"resume"}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    assert_eq!(r.get("draining"), Some(&Json::Bool(false)));
    let r = c.roundtrip(r#"{"id":6,"op":"score","text":"x"}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    handle.shutdown();
}

/// A long mixed-shape serve run holds the arena footprint steady.
///
/// This drives the native backend's decode path directly — the exact
/// calls `NativeEngine` issues per request — because the observable
/// (`NativeBackend::arena_retained_bytes`) lives on the backend. Prompt
/// lengths cycle through every size the server could see, interleaved
/// with incremental decode steps, in both precisions. The best-fit free
/// list used to grow without bound under this churn: every novel
/// intermediate shape left another buffer behind. With the bounded
/// arena (`linalg::Arena`, default 256 MiB idle cap) the footprint must
/// stop growing once every shape has been seen: after a warmup cycle,
/// each later cycle ends at exactly the same retained-byte count.
#[test]
fn mixed_shape_decode_churn_holds_arena_footprint_steady() {
    use spectron::config::Registry;
    use spectron::runtime::{Backend, NativeBackend, Precision};

    let reg = Registry::load().unwrap();
    let mut cfg = reg.variant("fact-z0-spectron").unwrap().clone();
    cfg.model.vocab = 48;
    cfg.model.seq_len = 12;
    cfg.batch = 2;

    for precision in [Precision::F64, Precision::F32] {
        let mut be = NativeBackend::with_opts(&cfg, 1, precision).unwrap();
        let state = be.init_state(9, &[10.0, 0.01, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        let params_end = be.manifest().params_end;
        let prefix = be.upload_prefix(&state[..params_end]).unwrap();
        let dm = be.decode_model(&prefix).unwrap();

        let cap = cfg.model.seq_len + 1; // KV capacity per decode session
        let mut warm = None;
        for cycle in 0..4 {
            for len in 1..=cfg.model.seq_len {
                let mut st = be.decode_open(&dm).unwrap();
                let prompt: Vec<i32> =
                    (0..len).map(|i| ((i * 7 + len) % cfg.model.vocab) as i32).collect();
                be.decode_prefill(&prefix, &dm, &mut st, &prompt).unwrap();
                for t in 0..(cap - len).min(3) {
                    let tok = ((len + t) % cfg.model.vocab) as i32;
                    be.decode_step(&prefix, &dm, &mut st, tok).unwrap();
                }
                be.decode_close(st);
            }
            let retained = be.arena_retained_bytes();
            match warm {
                // warmup cycle: every shape is now cached
                None => warm = Some(retained),
                Some(w) => assert_eq!(
                    retained, w,
                    "cycle {cycle} moved the arena footprint ({precision:?}): {w} -> {retained}"
                ),
            }
        }
        assert!(warm.unwrap() > 0, "churn should exercise the arena ({precision:?})");
    }
}
