//! Property-based tests over the coordinator invariants, the native
//! backend's kernels, and the in-tree substrates, via the seeded harness
//! in `spectron::util::prop`
//! (replay any failure with `PROP_REPLAY=1 PROP_SEED=<seed> cargo test`).

use spectron::config::Registry;
use spectron::coordinator::parallel::tree_allreduce_mean;
use spectron::linalg::{self, Mat};
use spectron::monitor::detect::LossSpikeDetector;
use spectron::runtime::native::kernels::{newton_schulz_stacked, power_iter, K_NS};
use spectron::runtime::native::optim::spectron_pair_update;
use spectron::runtime::NativeBackend;
use spectron::data::bpe::Bpe;
use spectron::data::corpus::{Corpus, CorpusCfg};
use spectron::data::dataset::{Dataset, Split};
use spectron::train::schedule::Schedule;
use spectron::util::json::Json;
use spectron::util::prop::{check, f64_in, usize_in, vec_f64};
use spectron::util::rng::Pcg64;
use spectron::util::stats::{linreg, quadfit};

#[test]
fn prop_bpe_roundtrip_any_ascii() {
    let bpe = Bpe::train(
        "the quick brown fox jumps over the lazy dog 0123456789 again and again",
        300,
    );
    check("bpe roundtrip", |rng| {
        let len = usize_in(rng, 0, 120);
        let s: String = (0..len)
            .map(|_| (rng.below(95) as u8 + 32) as char) // printable ascii
            .collect();
        let dec = bpe.decode(&bpe.encode(&s));
        if dec == s {
            Ok(())
        } else {
            Err(format!("{s:?} -> {dec:?}"))
        }
    });
}

#[test]
fn prop_bpe_ids_in_vocab() {
    let bpe = Bpe::train("aaa bbb aab abb aabb abab", 280);
    check("bpe ids bounded", |rng| {
        let len = usize_in(rng, 1, 60);
        let s: String = (0..len)
            .map(|_| *rng.choice(&['a', 'b', ' ', 'c']))
            .collect();
        for id in bpe.encode(&s) {
            if !(0..280).contains(&id) {
                return Err(format!("id {id} out of vocab"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_corpus_documents_deterministic() {
    let c1 = Corpus::new(CorpusCfg::default());
    let c2 = Corpus::new(CorpusCfg::default());
    check("corpus determinism", |rng| {
        let d = rng.below(100_000);
        if c1.document(d) == c2.document(d) {
            Ok(())
        } else {
            Err(format!("doc {d} differs"))
        }
    });
}

#[test]
fn prop_dataset_shards_partition_windows() {
    let corpus = Corpus::new(CorpusCfg::default());
    let bpe = Bpe::train(&corpus.text_range(1, 60), 300);
    let ds = Dataset::build_with(&corpus, &bpe, 400, 64);
    let total = ds.n_windows(Split::Train);
    check("shards partition", |rng| {
        let n_workers = usize_in(rng, 1, 6);
        let mut seen = vec![0usize; total];
        for w in 0..n_workers {
            // shard membership is idx % n == w by construction; verify via
            // the public iterator by drawing a full epoch per shard
            let batch = 1;
            let mut it = ds.batches_sharded(Split::Train, batch, 9, w, n_workers);
            let shard_size = (0..total).filter(|i| i % n_workers == w).count();
            for _ in 0..shard_size {
                let b = it.next_batch();
                let idx = (0..total)
                    .find(|&i| ds.window(Split::Train, i) == &b[..])
                    .ok_or("window not found")?;
                seen[idx] += 1;
            }
        }
        if seen.iter().all(|&c| c == 1) {
            Ok(())
        } else {
            Err(format!(
                "coverage: {} missing, {} dup",
                seen.iter().filter(|&&c| c == 0).count(),
                seen.iter().filter(|&&c| c > 1).count()
            ))
        }
    });
}

/// The loss-spike detector's core soundness property: a monotone
/// non-increasing loss curve — any mix of plateaus, slow decay, and
/// cliff drops, at any scale — NEVER raises a spike, because the
/// z-score only fires above the trailing window mean
/// (DESIGN.md §Monitoring and sweeps).
#[test]
fn prop_loss_spike_never_fires_on_monotone_nonincreasing() {
    check("loss-spike monotone", |rng| {
        let mut d = LossSpikeDetector::default();
        let n = usize_in(rng, 1, 300);
        let mut loss = f64_in(rng, 1e-3, 20.0);
        for step in 0..n {
            // plateaus (no change), gentle decay, and occasional cliffs
            let dec = match rng.below(4) {
                0 => 0.0,
                1 => f64_in(rng, 0.0, 0.01) * loss,
                2 => f64_in(rng, 0.0, 0.1) * loss,
                _ => f64_in(rng, 0.0, 0.9) * loss,
            };
            loss = (loss - dec).max(0.0);
            if let Some(det) = d.push_loss(step, loss) {
                return Err(format!(
                    "fired at step {step} on a non-increasing curve: {}",
                    det.detail
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_tree_allreduce_matches_naive() {
    check("tree allreduce", |rng| {
        let n = usize_in(rng, 1, 9);
        let len = usize_in(rng, 1, 200);
        let bufs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..len).map(|_| rng.normal() as f32).collect())
            .collect();
        let naive: Vec<f32> = (0..len)
            .map(|i| bufs.iter().map(|b| b[i]).sum::<f32>() / n as f32)
            .collect();
        let tree = tree_allreduce_mean(bufs);
        for (a, b) in tree.iter().zip(&naive) {
            if (a - b).abs() > 1e-4 * (1.0 + b.abs()) {
                return Err(format!("{a} vs {b}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_schedule_bounded_and_warmup_monotone() {
    check("lr schedule invariants", |rng| {
        let s = Schedule {
            total_steps: usize_in(rng, 10, 5000),
            base_lr: f64_in(rng, 1e-4, 1.0),
            warmup_frac: f64_in(rng, 0.01, 0.3),
        };
        let warm = (s.warmup_frac * s.total_steps as f64).max(1.0) as usize;
        let mut prev = 0.0;
        for t in 0..s.total_steps {
            let lr = s.lr_at(t);
            if !(lr >= -1e-12 && lr <= s.base_lr * (1.0 + 1e-9)) {
                return Err(format!("lr {lr} out of [0, base] at {t}"));
            }
            if t < warm && lr + 1e-12 < prev {
                return Err(format!("warmup not monotone at {t}"));
            }
            prev = lr;
        }
        // end of schedule decays to (near) zero
        let end = s.lr_at(s.total_steps - 1);
        if end > 0.05 * s.base_lr {
            return Err(format!("end lr {end} too high"));
        }
        Ok(())
    });
}

#[test]
fn prop_quadfit_recovers_random_parabolas() {
    check("quadfit vertex", |rng| {
        let c2 = f64_in(rng, 0.1, 5.0);
        let vx = f64_in(rng, -10.0, 10.0);
        let c0 = f64_in(rng, -5.0, 5.0);
        let xs: Vec<f64> = (0..12).map(|i| vx - 6.0 + i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| c0 + c2 * (x - vx).powi(2)).collect();
        let c = quadfit(&xs, &ys);
        let vertex = -c[1] / (2.0 * c[2]);
        if (vertex - vx).abs() < 1e-6 {
            Ok(())
        } else {
            Err(format!("vertex {vertex} != {vx}"))
        }
    });
}

#[test]
fn prop_linreg_recovers_random_lines() {
    check("linreg", |rng| {
        let a = f64_in(rng, -10.0, 10.0);
        let b = f64_in(rng, -3.0, 3.0);
        let xs = vec_f64(rng, 20, -5.0, 5.0);
        let ys: Vec<f64> = xs.iter().map(|x| a + b * x).collect();
        let (fa, fb, r2) = linreg(&xs, &ys);
        if (fa - a).abs() < 1e-7 && (fb - b).abs() < 1e-7 && r2 > 0.999 {
            Ok(())
        } else {
            Err(format!("fit ({fa}, {fb}, {r2}) != ({a}, {b})"))
        }
    });
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn random_json(rng: &mut Pcg64, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.normal() * 100.0 * 8.0).round() / 8.0),
            3 => {
                let len = rng.below(12) as usize;
                Json::Str(
                    (0..len)
                        .map(|_| (rng.below(94) as u8 + 32) as char)
                        .collect(),
                )
            }
            4 => Json::Arr(
                (0..rng.below(4)).map(|_| random_json(rng, depth - 1)).collect(),
            ),
            _ => Json::Obj(
                (0..rng.below(4))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    check("json roundtrip", |rng| {
        let v = random_json(rng, 3);
        let re = Json::parse(&v.to_string()).map_err(|e| e.to_string())?;
        if re == v {
            Ok(())
        } else {
            Err(format!("{v} != {re}"))
        }
    });
}

#[test]
fn prop_checkpoint_roundtrip_random_states() {
    check("checkpoint roundtrip", |rng| {
        let len = usize_in(rng, 1, 5000);
        let state: Vec<f32> = (0..len).map(|_| rng.normal() as f32).collect();
        let p = std::env::temp_dir().join(format!(
            "spectron-prop-{}-{}.ckpt",
            std::process::id(),
            rng.below(u64::MAX)
        ));
        spectron::train::checkpoint::save(&p, "v", &state).map_err(|e| e.to_string())?;
        let (_, loaded) = spectron::train::checkpoint::load(&p).map_err(|e| e.to_string())?;
        std::fs::remove_file(&p).ok();
        if loaded == state {
            Ok(())
        } else {
            Err("state mismatch".into())
        }
    });
}

// ---------------------------------------------------------------------------
// native-backend kernels (DESIGN.md §Backends)
// ---------------------------------------------------------------------------

/// Newton-Schulz output is orthogonal: `QᵀQ ≈ I` within the Jordan
/// quintic's convergence band, across random tall shapes. `m >= 4r`
/// keeps random-Gaussian singular values bounded away from zero, where
/// 5 iterations provably land in the band.
#[test]
fn prop_newton_schulz_output_is_orthogonal() {
    check("newton-schulz orthogonality", |rng| {
        let r = usize_in(rng, 1, 14);
        let m = usize_in(rng, 4 * r, (4 * r).max(64));
        let g = Mat::randn(m, r, rng);
        let o = linalg::newton_schulz(&g, K_NS);
        let gram = o.t().matmul(&o);
        for i in 0..r {
            let d = gram.at(i, i);
            if !(0.35..1.65).contains(&d) {
                return Err(format!("gram[{i}][{i}] = {d} ({m}x{r})"));
            }
            for j in 0..r {
                if i != j && gram.at(i, j).abs() > 0.45 {
                    return Err(format!(
                        "gram[{i}][{j}] = {} ({m}x{r})",
                        gram.at(i, j)
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Power iteration converges to the dominant singular value: on a
/// constructed rank-2 operator with a known spectrum, the kernel
/// recovers sigma_1 — both in one deep call and through the optimizer's
/// persisted-vector regime (many 1-step calls feeding u back in).
#[test]
fn prop_power_iter_converges_to_dominant_sigma() {
    check("power iteration", |rng| {
        let m = usize_in(rng, 6, 40);
        let n = usize_in(rng, 4, 30);
        let sigma1 = f64_in(rng, 1.0, 8.0);
        let sigma2 = sigma1 * f64_in(rng, 0.1, 0.7);
        // orthonormal pairs via Gram-Schmidt
        let mut u1: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let mut u2: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let mut v1: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut v2: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        normalize(&mut u1);
        project_out(&mut u2, &u1);
        normalize(&mut u2);
        normalize(&mut v1);
        project_out(&mut v2, &v1);
        normalize(&mut v2);
        let mut w = Mat::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                *w.at_mut(i, j) = sigma1 * u1[i] * v1[j] + sigma2 * u2[i] * v2[j];
            }
        }
        let u0: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let (sigma, u) = power_iter(&w, &u0, 60);
        if (sigma - sigma1).abs() / sigma1 > 0.01 {
            return Err(format!("deep: {sigma} vs {sigma1}"));
        }
        // persisted-u regime: k=1 per call, u handed back each time
        let mut u_p: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let mut sigma_p = 0.0;
        for _ in 0..30 {
            let (s, un) = power_iter(&w, &u_p, 1);
            sigma_p = s;
            u_p = un;
        }
        if (sigma_p - sigma1).abs() / sigma1 > 0.02 {
            return Err(format!("persisted: {sigma_p} vs {sigma1}"));
        }
        // the left vector aligns with u1 up to sign
        let align = u.iter().zip(&u1).map(|(a, b)| a * b).sum::<f64>().abs();
        if align < 0.99 {
            return Err(format!("u alignment {align}"));
        }
        Ok(())
    });
}

/// The Spectron-renormalized update respects the paper's spectral bound:
/// with warm persisted power-iteration vectors, the composite update
/// `dW = A'B'ᵀ - ABᵀ` has `||dW||_2 <= ~eta` (Eq. 13-16; the slack
/// covers the Newton-Schulz band and the k=1 sigma estimate — the
/// tolerance policy is documented in DESIGN.md §Backends).
#[test]
fn prop_spectron_update_respects_spectral_bound() {
    check("spectron bound", |rng| {
        let r = usize_in(rng, 2, 10);
        let m = usize_in(rng, 2 * r, 48);
        let n = usize_in(rng, 2 * r, 48);
        let scale_a = f64_in(rng, 0.2, 3.0);
        let scale_b = f64_in(rng, 0.2, 3.0);
        let a = Mat::randn(m, r, rng).scale(scale_a / (m as f64).sqrt());
        let b = Mat::randn(n, r, rng).scale(scale_b / (n as f64).sqrt());
        let mom_a = Mat::randn(m, r, rng);
        let mom_b = Mat::randn(n, r, rng);
        let eta = f64_in(rng, 0.01, 1.0);
        // warm u like training does (the vectors persist across steps)
        let (_, u_a) = power_iter(&a, &(0..m).map(|_| rng.normal()).collect::<Vec<_>>(), 5);
        let (_, u_b) = power_iter(&b, &(0..n).map(|_| rng.normal()).collect::<Vec<_>>(), 5);
        let (a2, b2, rho) = spectron_pair_update(&a, &b, &mom_a, &mom_b, &u_a, &u_b, eta, 0.0);
        if !(rho > 0.0 && rho <= eta) {
            return Err(format!("rho {rho} outside (0, eta={eta}]"));
        }
        // ||dW||_2 through the implicit factored operator
        let dmv = |x: &[f64]| -> Vec<f64> {
            let y1 = a2.matvec(&b2.matvec_t(x));
            let y0 = a.matvec(&b.matvec_t(x));
            y1.iter().zip(&y0).map(|(p, q)| p - q).collect()
        };
        let dmt = |y: &[f64]| -> Vec<f64> {
            let x1 = b2.matvec(&a2.matvec_t(y));
            let x0 = b.matvec(&a.matvec_t(y));
            x1.iter().zip(&x0).map(|(p, q)| p - q).collect()
        };
        let dw = linalg::spectral_norm_op(dmv, dmt, n, 50, rng);
        if dw > 1.5 * eta {
            return Err(format!("||dW|| = {dw} > 1.5 * eta ({eta}), rho {rho}"));
        }
        // each factor moves by at most ~rho (NS band slack)
        let da = a2.sub(&a);
        let db = b2.sub(&b);
        let sda = linalg::spectral_norm(&da, 50, rng);
        let sdb = linalg::spectral_norm(&db, 50, rng);
        if sda > 1.35 * rho || sdb > 1.35 * rho {
            return Err(format!("factor step too big: {sda}/{sdb} vs rho {rho}"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// tensor core: parallel == serial bit-identity
// (DESIGN.md §Native tensor core; docs/adr/005-parallel-tensor-core.md)
// ---------------------------------------------------------------------------

/// Row-parallel and in-place matmuls are bit-identical to the serial
/// allocating kernel at every thread count, across random shapes
/// straddling the 64-wide tile edge.
#[test]
fn prop_matmul_parallel_and_inplace_bit_identical() {
    check("matmul parallel bits", |rng| {
        let m = usize_in(rng, 1, 150);
        let k = usize_in(rng, 1, 150);
        let n = usize_in(rng, 1, 150);
        let a = Mat::randn(m, k, rng);
        let b = Mat::randn(k, n, rng);
        let want = a.matmul(&b);
        for &threads in &[1usize, 2, 3, 8] {
            let got = a.matmul_par(&b, threads);
            for (x, y) in want.data.iter().zip(&got.data) {
                if x.to_bits() != y.to_bits() {
                    return Err(format!("{m}x{k}x{n} threads={threads}"));
                }
            }
        }
        let mut reused = Mat::zeros(2, 2);
        reused.data.fill(3.0); // dirty buffer must not leak into the result
        a.matmul_into(&b, &mut reused);
        for (x, y) in want.data.iter().zip(&reused.data) {
            if x.to_bits() != y.to_bits() {
                return Err(format!("matmul_into {m}x{k}x{n}"));
            }
        }
        Ok(())
    });
}

/// The stacked Newton-Schulz layer fan-out is bit-identical to the
/// serial per-layer loop at every thread count.
#[test]
fn prop_stacked_newton_schulz_parallel_matches_serial() {
    check("stacked NS parallel bits", |rng| {
        let layers = usize_in(rng, 1, 5);
        let r = usize_in(rng, 1, 8);
        let m = usize_in(rng, 1, 40);
        let data: Vec<f64> = (0..layers * m * r).map(|_| rng.normal()).collect();
        let want = newton_schulz_stacked(&data, layers, m, r, 1);
        for &threads in &[2usize, 3, 8] {
            let got = newton_schulz_stacked(&data, layers, m, r, threads);
            for (x, y) in want.iter().zip(&got) {
                if x.to_bits() != y.to_bits() {
                    return Err(format!("layers={layers} {m}x{r} threads={threads}"));
                }
            }
        }
        Ok(())
    });
}

/// A FULL native train step — forward, hand-derived backward, Spectron
/// optimizer, telemetry — is bit-identical across thread budgets, for
/// random seeds and batches on a shrunken z0 model.
#[test]
fn prop_native_train_step_parallel_bit_identity() {
    let reg = Registry::load().unwrap();
    let mut cfg = reg.variant("fact-z0-spectron").unwrap().clone();
    cfg.model.vocab = 48;
    cfg.model.seq_len = 10;
    cfg.batch = 2;
    let serial = NativeBackend::with_threads(&cfg, 1).unwrap();
    let (b, w) = (cfg.batch, cfg.model.seq_len + 1);
    let vocab = cfg.model.vocab;
    check("native step parallel bits", |rng| {
        let threads = *rng.choice(&[2usize, 3, 8]);
        let seed = rng.below(1000);
        let knobs = [20.0, 0.02, 0.01, 0.1, 0.0, 0.0, 0.0, 0.0];
        let s0 = serial.init_state(seed, &knobs);
        let toks: Vec<i32> = (0..b * w).map(|_| rng.below(vocab as u64) as i32).collect();
        let want = serial.step_state(&s0, &toks).map_err(|e| e.to_string())?;
        let par = NativeBackend::with_threads(&cfg, threads).map_err(|e| e.to_string())?;
        let got = par.step_state(&s0, &toks).map_err(|e| e.to_string())?;
        for (i, (a, c)) in want.iter().zip(&got).enumerate() {
            if a.to_bits() != c.to_bits() {
                return Err(format!("state slot {i} differs at threads={threads}"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// SIMD microkernels: vectorized == forced-scalar bit-identity
// (DESIGN.md §Native tensor core; docs/adr/010-simd-microkernels.md)
// ---------------------------------------------------------------------------

/// Serializes tests that pin the process-wide SIMD dispatch override:
/// `simd::force` is global, so two tests flipping it concurrently under
/// the threaded harness would observe each other's tier mid-compare.
static SIMD_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn same_bits_f64(want: &[f64], got: &[f64]) -> bool {
    want.len() == got.len()
        && want.iter().zip(got).all(|(a, b)| a.to_bits() == b.to_bits())
}

fn same_bits_f32(want: &[f32], got: &[f32]) -> bool {
    want.len() == got.len()
        && want.iter().zip(got).all(|(a, b)| a.to_bits() == b.to_bits())
}

/// Every dispatched kernel — matmul (row-parallel at 1/2/4 threads),
/// matvec, transposed matvec, and the blocked transpose — is
/// bit-identical to the forced-scalar portable path in both precisions,
/// across shapes straddling the vector lane widths (4-wide f64 /
/// 8-wide f32, including remainder lanes) and the per-`Elem` tile
/// edges (64 / 128). On machines with no vector tier this degenerates
/// to scalar-vs-scalar, which still exercises the force plumbing.
#[test]
fn prop_simd_matches_scalar_bits() {
    use spectron::linalg::simd;
    let _guard = SIMD_LOCK.lock().unwrap();
    let vec_lvl = simd::detected();
    check("simd vs scalar bits", |rng| {
        let dims = [1usize, 2, 3, 4, 5, 7, 8, 9, 15, 17, 31, 33, 63, 64, 65, 127, 129];
        let m = *rng.choice(&dims);
        let k = *rng.choice(&dims);
        let n = *rng.choice(&dims);
        let threads = *rng.choice(&[1usize, 2, 4]);

        let a = Mat::randn(m, k, rng);
        let b = Mat::randn(k, n, rng);
        let x: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let af = Mat::<f32>::randn(m, k, rng);
        let bf = Mat::<f32>::randn(k, n, rng);
        let xf: Vec<f32> = (0..k).map(|_| rng.normal() as f32).collect();
        let yf: Vec<f32> = (0..m).map(|_| rng.normal() as f32).collect();

        simd::force(Some(simd::Level::Scalar));
        let mm_s = a.matmul_par(&b, threads);
        let mv_s = a.matvec(&x);
        let mt_s = a.matvec_t(&y);
        let tr_s = a.t();
        let fmm_s = af.matmul_par(&bf, threads);
        let fmv_s = af.matvec(&xf);
        let fmt_s = af.matvec_t(&yf);
        let ftr_s = af.t();

        simd::force(Some(vec_lvl));
        let mm_v = a.matmul_par(&b, threads);
        let mv_v = a.matvec(&x);
        let mt_v = a.matvec_t(&y);
        let tr_v = a.t();
        let fmm_v = af.matmul_par(&bf, threads);
        let fmv_v = af.matvec(&xf);
        let fmt_v = af.matvec_t(&yf);
        let ftr_v = af.t();
        simd::force(None);

        let tag = format!("{m}x{k}x{n} threads={threads} tier={}", vec_lvl.name());
        if !same_bits_f64(&mm_s.data, &mm_v.data) {
            return Err(format!("matmul f64 {tag}"));
        }
        if !same_bits_f64(&mv_s, &mv_v) {
            return Err(format!("matvec f64 {tag}"));
        }
        if !same_bits_f64(&mt_s, &mt_v) {
            return Err(format!("matvec_t f64 {tag}"));
        }
        if !same_bits_f64(&tr_s.data, &tr_v.data) {
            return Err(format!("transpose f64 {tag}"));
        }
        if !same_bits_f32(&fmm_s.data, &fmm_v.data) {
            return Err(format!("matmul f32 {tag}"));
        }
        if !same_bits_f32(&fmv_s, &fmv_v) {
            return Err(format!("matvec f32 {tag}"));
        }
        if !same_bits_f32(&fmt_s, &fmt_v) {
            return Err(format!("matvec_t f32 {tag}"));
        }
        if !same_bits_f32(&ftr_s.data, &ftr_v.data) {
            return Err(format!("transpose f32 {tag}"));
        }
        Ok(())
    });
}

/// A FULL native train step — forward, backward, Spectron optimizer
/// (every elementwise update now routed through the dispatch table),
/// telemetry — is bit-identical between the forced-scalar table and the
/// detected vector tier (the `REPRO_SIMD=off` vs `auto` contract), at
/// thread budgets 1/2/4.
#[test]
fn prop_native_train_step_simd_bit_identity() {
    use spectron::linalg::simd;
    let _guard = SIMD_LOCK.lock().unwrap();
    let vec_lvl = simd::detected();
    let reg = Registry::load().unwrap();
    let mut cfg = reg.variant("fact-z0-spectron").unwrap().clone();
    cfg.model.vocab = 48;
    cfg.model.seq_len = 10;
    cfg.batch = 2;
    let (b, w) = (cfg.batch, cfg.model.seq_len + 1);
    let vocab = cfg.model.vocab;
    check("native step simd bits", |rng| {
        let threads = *rng.choice(&[1usize, 2, 4]);
        let seed = rng.below(1000);
        let knobs = [20.0, 0.02, 0.01, 0.1, 0.0, 0.0, 0.0, 0.0];
        let be = NativeBackend::with_threads(&cfg, threads).map_err(|e| e.to_string())?;
        let s0 = be.init_state(seed, &knobs);
        let toks: Vec<i32> = (0..b * w).map(|_| rng.below(vocab as u64) as i32).collect();
        simd::force(Some(simd::Level::Scalar));
        let want = be.step_state(&s0, &toks);
        simd::force(Some(vec_lvl));
        let got = be.step_state(&s0, &toks);
        simd::force(None);
        let want = want.map_err(|e| e.to_string())?;
        let got = got.map_err(|e| e.to_string())?;
        for (i, (a, c)) in want.iter().zip(&got).enumerate() {
            if a.to_bits() != c.to_bits() {
                return Err(format!(
                    "state slot {i} differs at threads={threads} tier={}",
                    vec_lvl.name()
                ));
            }
        }
        Ok(())
    });
}

/// The f32 compute path contract (docs/adr/008-f32-compute-path.md):
/// for random shrunken variants, the f32 forward's logits (via
/// `grad_vec`'s loss and `logits_at`) are bit-identical across thread
/// budgets 1/2/4 and agree with the f64 path within a tolerance band.
#[test]
fn prop_f32_forward_matches_f64() {
    use spectron::runtime::{Backend, Precision};
    let reg = Registry::load().unwrap();
    let bases = ["fact-z0-spectron", "fact-s-sgd"];
    check("f32 forward vs f64", |rng| {
        let base = *rng.choice(&bases);
        let mut cfg = reg.variant(base).map_err(|e| e.to_string())?.clone();
        cfg.model.vocab = usize_in(rng, 24, 48);
        cfg.model.seq_len = usize_in(rng, 6, 12);
        cfg.batch = 2;
        let seed = rng.below(1000);
        let knobs = [20.0, 0.02, 0.01, 0.1, 0.0, 0.0, 0.0, 0.0];
        let f64_be = NativeBackend::with_opts(&cfg, 1, Precision::F64)
            .map_err(|e| e.to_string())?;
        let state = f64_be.init_state(seed, &knobs);
        let params_end = f64_be.manifest().params_end;
        let b = cfg.batch;
        let t = cfg.model.seq_len;
        let vocab = cfg.model.vocab;
        let toks: Vec<i32> = (0..b * t).map(|_| rng.below(vocab as u64) as i32).collect();
        let pos: Vec<i32> = (0..b).map(|_| rng.below(t as u64) as i32).collect();
        let want = f64_be
            .logits_at(&state[..params_end], &toks, &pos)
            .map_err(|e| e.to_string())?;
        let mut f32_runs = Vec::new();
        for &threads in &[1usize, 2, 4] {
            let be = NativeBackend::with_opts(&cfg, threads, Precision::F32)
                .map_err(|e| e.to_string())?;
            let got = be
                .logits_at(&state[..params_end], &toks, &pos)
                .map_err(|e| e.to_string())?;
            if got.len() != want.len() {
                return Err(format!("{base}: f32 logits len {}", got.len()));
            }
            f32_runs.push(got);
        }
        // f32 is bit-identical to itself across thread counts
        for (threads, got) in [2usize, 4].iter().zip(&f32_runs[1..]) {
            for (j, (a, c)) in f32_runs[0].iter().zip(got).enumerate() {
                if a.to_bits() != c.to_bits() {
                    return Err(format!(
                        "{base}: f32 logit {j} differs at threads={threads}"
                    ));
                }
            }
        }
        // ... and tracks f64 within the tolerance band (logits are O(1)
        // post-rms-norm products; depth amplifies rounding, so scale the
        // band by the magnitude of the pair)
        for (j, (a, c)) in want.iter().zip(&f32_runs[0]).enumerate() {
            let tol = 5e-3 * (1.0 + a.abs().max(c.abs()));
            if (a - c).abs() > tol {
                return Err(format!(
                    "{base}: logit {j} f64 {a} vs f32 {c} (tol {tol})"
                ));
            }
        }
        Ok(())
    });
}

/// The serving KV-cache invariant
/// (docs/adr/006-kv-cache-continuous-batching.md): incremental decode
/// through the Backend API — prefill once, then one token per step — is
/// bit-identical to re-running the full forward over the whole history,
/// at EVERY decode position, for random shrunken variants across two
/// optimizer state layouts, random prompts, and thread budgets 1/2/4.
#[test]
fn prop_kv_cache_matches_full_forward() {
    use spectron::runtime::{Backend, DecodeModel};
    let reg = Registry::load().unwrap();
    let bases = ["fact-z0-spectron", "fact-s-sgd"];
    check("kv cache vs full forward bits", |rng| {
        let base = *rng.choice(&bases);
        let mut cfg = reg.variant(base).map_err(|e| e.to_string())?.clone();
        cfg.model.vocab = usize_in(rng, 24, 48);
        cfg.model.seq_len = usize_in(rng, 6, 12);
        cfg.batch = 2;
        let vocab = cfg.model.vocab as u64;
        let seed = rng.below(1000);
        let knobs = [20.0, 0.02, 0.01, 0.1, 0.0, 0.0, 0.0, 0.0];
        let prompt: Vec<i32> =
            (0..usize_in(rng, 1, 4)).map(|_| rng.below(vocab) as i32).collect();
        // pre-draw the decode continuation so every thread budget replays
        // the exact same token sequence
        let steps = usize_in(rng, 2, 4);
        let cont: Vec<i32> = (0..steps).map(|_| rng.below(vocab) as i32).collect();
        for &threads in &[1usize, 2, 4] {
            let mut be =
                NativeBackend::with_threads(&cfg, threads).map_err(|e| e.to_string())?;
            let state = be.init_state(seed, &knobs);
            let params_end = be.manifest().params_end;
            let prefix =
                be.upload_prefix(&state[..params_end]).map_err(|e| e.to_string())?;
            let dm = be.decode_model(&prefix).map_err(|e| e.to_string())?;
            let DecodeModel::Native(m) = &dm else {
                return Err("native backend must decode natively".into());
            };
            let m = m.clone();
            let mut st = be.decode_open(&dm).map_err(|e| e.to_string())?;
            let mut hist = prompt.clone();
            let mut got = be
                .decode_prefill(&prefix, &dm, &mut st, &prompt)
                .map_err(|e| e.to_string())?;
            // step 0 checks the prefill logits; steps 1..=N each feed one
            // continuation token through the cache first
            for step in 0..=steps {
                if step > 0 {
                    let tok = cont[step - 1];
                    hist.push(tok);
                    got = be
                        .decode_step(&prefix, &dm, &mut st, tok)
                        .map_err(|e| e.to_string())?;
                }
                if st.positions() != hist.len() {
                    return Err(format!(
                        "{base}: cache holds {} positions, history has {}",
                        st.positions(),
                        hist.len()
                    ));
                }
                let (logits, _) =
                    m.forward(&hist, 1, hist.len()).map_err(|e| e.to_string())?;
                let v = m.vocab;
                let want = &logits.data[(hist.len() - 1) * v..hist.len() * v];
                if got.len() != v {
                    return Err(format!("{base}: logits len {} != {v}", got.len()));
                }
                for (j, (a, b)) in got.iter().zip(want).enumerate() {
                    if a.to_bits() != (*b as f32).to_bits() {
                        return Err(format!(
                            "{base}: threads={threads} step={step} logit {j}: \
                             cached {a} vs full {b}"
                        ));
                    }
                }
            }
            be.decode_close(st);
        }
        Ok(())
    });
}

fn normalize(x: &mut [f64]) {
    let n = x.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-300);
    for v in x.iter_mut() {
        *v /= n;
    }
}

fn project_out(x: &mut [f64], dir: &[f64]) {
    let d: f64 = x.iter().zip(dir).map(|(a, b)| a * b).sum();
    for (v, u) in x.iter_mut().zip(dir) {
        *v -= d * u;
    }
}

#[test]
fn prop_rng_below_is_bounded() {
    check("rng below bounds", |rng| {
        let n = 1 + rng.below(1_000_000);
        for _ in 0..100 {
            let x = rng.below(n);
            if x >= n {
                return Err(format!("{x} >= {n}"));
            }
        }
        Ok(())
    });
}
