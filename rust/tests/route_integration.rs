//! End-to-end router tests (DESIGN.md §Routing): byte-exact pass-through
//! against a stub replica, routed mock fleets, retry/backoff on sheds,
//! drain/resume rolling-restart cycles, transport chaos through the
//! [`ChaosProxy`], and a real SIGKILL failover test against supervised
//! child `repro serve --mock` processes.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use spectron::serve::route::pool::rendezvous_pick;
use spectron::serve::{
    ChaosPlan, ChaosProxy, MockEngine, RouteCfg, Router, RouterHandle, ServeCfg,
    Server, ServerHandle, SpawnSpec, Supervisor,
};
use spectron::util::json::Json;

/// Line client with a read timeout so a router bug fails instead of
/// hanging; `recv_raw` exposes the exact bytes for identity checks.
struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: impl std::net::ToSocketAddrs) -> Client {
        let stream = TcpStream::connect(addr).expect("connect");
        stream
            .set_read_timeout(Some(Duration::from_secs(20)))
            .expect("read timeout");
        Client {
            reader: BufReader::new(stream.try_clone().expect("clone")),
            writer: stream,
        }
    }

    fn send(&mut self, line: &str) {
        writeln!(self.writer, "{line}").expect("send");
        self.writer.flush().expect("flush");
    }

    fn recv_raw(&mut self) -> String {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).expect("recv");
        assert!(n > 0, "connection closed unexpectedly");
        line.trim_end_matches('\n').to_string()
    }

    fn recv(&mut self) -> Json {
        let raw = self.recv_raw();
        Json::parse(&raw).expect("response is json")
    }

    fn roundtrip(&mut self, line: &str) -> Json {
        self.send(line);
        self.recv()
    }
}

const PONG: &str = r#"{"ok":true,"pong":true,"draining":false}"#;

/// A scripted replica: every non-empty line goes through `handler`;
/// `Some(reply)` is written back verbatim, `None` drops the connection.
/// Records every received line (probes included) in `seen`.
struct StubReplica {
    addr: String,
    seen: Arc<Mutex<Vec<String>>>,
    stop: Arc<AtomicBool>,
}

fn stub_replica<F>(handler: F) -> StubReplica
where
    F: Fn(&str) -> Option<String> + Send + Sync + 'static,
{
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind stub");
    let addr = listener.local_addr().unwrap().to_string();
    listener.set_nonblocking(true).expect("nonblocking");
    let seen = Arc::new(Mutex::new(Vec::new()));
    let stop = Arc::new(AtomicBool::new(false));
    let handler = Arc::new(handler);
    {
        let (seen, stop) = (seen.clone(), stop.clone());
        std::thread::spawn(move || loop {
            if stop.load(Ordering::SeqCst) {
                return;
            }
            match listener.accept() {
                Ok((conn, _)) => {
                    let (seen, stop, handler) =
                        (seen.clone(), stop.clone(), handler.clone());
                    std::thread::spawn(move || {
                        conn.set_read_timeout(Some(Duration::from_millis(50))).ok();
                        let mut w = conn.try_clone().expect("clone");
                        let mut reader = BufReader::new(conn);
                        let mut line = String::new();
                        loop {
                            if stop.load(Ordering::SeqCst) {
                                return;
                            }
                            match reader.read_line(&mut line) {
                                Ok(0) => return,
                                Ok(_) if line.ends_with('\n') => {
                                    let t = line.trim().to_string();
                                    line.clear();
                                    if t.is_empty() {
                                        continue;
                                    }
                                    seen.lock().unwrap().push(t.clone());
                                    match handler(&t) {
                                        Some(reply) => {
                                            if writeln!(w, "{reply}")
                                                .and_then(|_| w.flush())
                                                .is_err()
                                            {
                                                return;
                                            }
                                        }
                                        None => return, // scripted drop
                                    }
                                }
                                Ok(_) => {} // partial line, keep reading
                                Err(e)
                                    if matches!(
                                        e.kind(),
                                        std::io::ErrorKind::WouldBlock
                                            | std::io::ErrorKind::TimedOut
                                    ) => {}
                                Err(_) => return,
                            }
                        }
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(_) => std::thread::sleep(Duration::from_millis(10)),
            }
        });
    }
    StubReplica { addr, seen, stop }
}

fn mock_server(max_batch: usize, max_wait: Duration) -> ServerHandle {
    let cfg = ServeCfg {
        addr: "127.0.0.1:0".into(),
        max_batch,
        max_wait,
        workers: 1,
        default_variant: Some("mock".into()),
        metrics_name: None,
        idle_timeout: None,
        queue_cap: 1024,
    };
    Server::spawn(
        cfg,
        MockEngine::factory(Duration::ZERO, Arc::new(Mutex::new(Vec::new()))),
    )
    .expect("spawn mock server")
}

/// Router config tuned for tests: fast probes, patient retries.
fn test_cfg() -> RouteCfg {
    let mut cfg = RouteCfg {
        addr: "127.0.0.1:0".into(),
        retries: 8,
        deadline: Duration::from_secs(10),
        retry_base: Duration::from_millis(20),
        retry_cap: Duration::from_millis(100),
        health_interval: Duration::from_millis(25),
        probe_timeout: Duration::from_millis(500),
        connect_timeout: Duration::from_millis(500),
        ..RouteCfg::default()
    };
    cfg.breaker.fail_threshold = 2;
    cfg.breaker.open_base = Duration::from_millis(50);
    cfg
}

fn router_over(addrs: Vec<String>, cfg: RouteCfg) -> RouterHandle {
    Router::spawn(cfg, addrs, None).expect("spawn router")
}

fn stat(j: &Json, key: &str) -> f64 {
    j.get(key)
        .unwrap_or_else(|| panic!("stat {key} missing in {j}"))
        .as_f64()
        .unwrap()
}

fn wait_until(what: &str, timeout: Duration, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + timeout;
    while Instant::now() < deadline {
        if cond() {
            return;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    panic!("timed out waiting for {what}");
}

#[test]
fn routed_replies_are_byte_identical_to_direct_ones() {
    // the stub answers with deliberately odd (but valid-JSON) bytes the
    // router would never produce itself; any re-rendering shows up as a
    // byte diff. The error reply is a *genuine* per-request error (not a
    // shed), so it must be forwarded, not retried.
    const WEIRD_OK: &str =
        r#"{ "id":"a" ,"ok":true,"nll": 1.50,  "note":"  spaced  out  " }"#;
    const WEIRD_ERR: &str = r#"{"id":"b","ok":false,"error":"model exploded (kept verbatim)"}"#;
    let route_reply = |line: &str| {
        if line.contains(r#""op":"ping""#) {
            Some(PONG.to_string())
        } else if line.contains(r#""id":"a""#) {
            Some(WEIRD_OK.to_string())
        } else if line.contains(r#""id":"b""#) {
            Some(WEIRD_ERR.to_string())
        } else {
            None
        }
    };
    let req_a = r#"{"id":"a","op":"score","text":"one two"}"#;
    let req_b = r#"{"id":"b","op":"generate","prompt":"x","max_tokens":3}"#;

    // direct transcript
    let direct = stub_replica(route_reply);
    let mut c = Client::connect(&direct.addr as &str);
    c.send(req_a);
    let direct_a = c.recv_raw();
    c.send(req_b);
    let direct_b = c.recv_raw();
    assert_eq!(direct_a, WEIRD_OK);
    assert_eq!(direct_b, WEIRD_ERR);

    // routed transcript — and routed *through a fault-free chaos proxy*,
    // which pins the proxy's transparency at the same time
    let routed = stub_replica(route_reply);
    let proxy = ChaosProxy::spawn(&routed.addr, ChaosPlan::new()).expect("proxy");
    let handle = router_over(vec![proxy.addr.to_string()], test_cfg());
    let mut c = Client::connect(handle.addr);
    c.send(req_a);
    assert_eq!(c.recv_raw(), direct_a, "ok reply must pass through verbatim");
    c.send(req_b);
    assert_eq!(c.recv_raw(), direct_b, "error reply must pass through verbatim");

    // the request lines the replica saw are byte-identical too
    let model_lines = |seen: &Arc<Mutex<Vec<String>>>| -> Vec<String> {
        seen.lock()
            .unwrap()
            .iter()
            .filter(|l| !l.contains(r#""op":"ping""#))
            .cloned()
            .collect()
    };
    assert_eq!(model_lines(&routed.seen), model_lines(&direct.seen));

    handle.shutdown();
    proxy.stop();
    direct.stop.store(true, Ordering::SeqCst);
    routed.stop.store(true, Ordering::SeqCst);
}

#[test]
fn trace_id_propagates_route_to_serve_and_back() {
    // the router forwards model ops verbatim, so the trace field rides
    // through to the replica; serve echoes it on the reply and the
    // router passes that back untouched — end-to-end request tracing
    // without a protocol version bump
    let server = mock_server(4, Duration::from_millis(5));
    let handle = router_over(vec![server.addr.to_string()], test_cfg());
    let mut c = Client::connect(handle.addr);
    let r = c.roundtrip(
        r#"{"id":1,"op":"generate","prompt":"a b","max_tokens":2,"trace":"req-abc-1"}"#,
    );
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
    assert_eq!(r.get("trace").and_then(Json::as_str), Some("req-abc-1"));
    // untraced requests stay untraced end to end — no key fabricated
    let r = c.roundtrip(r#"{"id":2,"op":"score","text":"x"}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
    assert!(r.get("trace").is_none(), "unexpected trace key: {r}");
    handle.shutdown();
    server.shutdown();
}

#[test]
fn router_answers_metrics_op_locally() {
    let server = mock_server(4, Duration::from_millis(5));
    let handle = router_over(vec![server.addr.to_string()], test_cfg());
    let mut c = Client::connect(handle.addr);
    c.roundtrip(r#"{"id":1,"op":"score","text":"warm"}"#);
    let r = c.roundtrip(r#"{"id":2,"op":"metrics"}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
    let text = r.get("metrics").unwrap().as_str().expect("metrics is text");
    let samples =
        spectron::obs::expo::parse_prometheus(text).expect("exposition parses");
    // the registry is process-global, so presence (not exact counts) is
    // the contract; route families prove the router rendered its own
    let req = samples
        .iter()
        .find(|(name, _)| name == "route_requests_total")
        .expect("route_requests_total present");
    assert!(req.1 >= 1.0, "routed request not counted: {}", req.1);
    assert!(
        samples.iter().any(|(n, _)| n == "route_forwards_total{replica=\"0\"}"),
        "per-replica forward series missing"
    );
    handle.shutdown();
    server.shutdown();
}

#[test]
fn router_parse_errors_match_serve_parse_errors() {
    // local router-side errors use the same renderer + messages as
    // serve, so even the failure surface is protocol-compatible
    let server = mock_server(4, Duration::from_millis(5));
    let mut direct = Client::connect(server.addr);
    direct.send("this is not json");
    let direct_bad = direct.recv_raw();
    direct.send(r#"{"id":1,"op":"fly"}"#);
    let direct_unknown = direct.recv_raw();

    let handle = router_over(vec![server.addr.to_string()], test_cfg());
    let mut routed = Client::connect(handle.addr);
    routed.send("this is not json");
    assert_eq!(routed.recv_raw(), direct_bad);
    routed.send(r#"{"id":1,"op":"fly"}"#);
    assert_eq!(routed.recv_raw(), direct_unknown);

    handle.shutdown();
    server.shutdown();
}

#[test]
fn routes_across_two_replicas_and_answers_everything() {
    let (s0, s1) = (
        mock_server(4, Duration::from_millis(5)),
        mock_server(4, Duration::from_millis(5)),
    );
    let handle = router_over(
        vec![s0.addr.to_string(), s1.addr.to_string()],
        test_cfg(),
    );
    let mut c = Client::connect(handle.addr);

    // router-level ping and stats answer locally
    let r = c.roundtrip(r#"{"id":"p","op":"ping"}"#);
    assert_eq!(r.get("pong"), Some(&Json::Bool(true)), "{r}");
    assert_eq!(r.get("healthy").unwrap().as_usize(), Some(2));

    // default-variant traffic spreads by id, every request answered once
    let n = 40;
    for i in 0..n {
        c.send(&format!(r#"{{"id":{i},"op":"score","text":"w{i}"}}"#));
    }
    let mut got = HashMap::new();
    for _ in 0..n {
        let r = c.recv();
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        *got.entry(r.get("id").unwrap().as_usize().unwrap()).or_insert(0) += 1;
    }
    assert_eq!(got.len(), n, "every id answered exactly once");

    let r = c.roundtrip(r#"{"id":"s","op":"stats"}"#);
    let stats = r.get("stats").unwrap();
    assert_eq!(stat(stats, "requests") as usize, n);
    assert_eq!(stat(stats, "errors") as usize, 0);
    let per = match stats.get("forwards_per_replica") {
        Some(Json::Arr(a)) => a.iter().map(|v| v.as_f64().unwrap()).collect::<Vec<_>>(),
        other => panic!("forwards_per_replica missing: {other:?}"),
    };
    assert_eq!(per.len(), 2);
    assert!(
        per[0] >= 5.0 && per[1] >= 5.0,
        "40 distinct ids should spread across both replicas, got {per:?}"
    );

    // explicit-variant traffic pins to one replica (session affinity)
    let before = per.clone();
    for i in 0..10 {
        c.send(&format!(
            r#"{{"id":"v{i}","op":"score","text":"x","variant":"mock"}}"#
        ));
    }
    for _ in 0..10 {
        let r = c.recv();
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    }
    let r = c.roundtrip(r#"{"id":"s2","op":"stats"}"#);
    let stats = r.get("stats").unwrap();
    let after = match stats.get("forwards_per_replica") {
        Some(Json::Arr(a)) => a.iter().map(|v| v.as_f64().unwrap()).collect::<Vec<_>>(),
        _ => unreachable!(),
    };
    let deltas: Vec<f64> = after.iter().zip(&before).map(|(a, b)| a - b).collect();
    assert!(
        deltas.contains(&10.0) && deltas.contains(&0.0),
        "same-variant requests must all land on one replica, got {deltas:?}"
    );

    handle.shutdown();
    s0.shutdown();
    s1.shutdown();
}

#[test]
fn overloaded_shed_is_retried_honoring_the_hint() {
    let attempts = Arc::new(AtomicUsize::new(0));
    let stub = {
        let attempts = attempts.clone();
        stub_replica(move |line| {
            if line.contains(r#""op":"ping""#) {
                return Some(PONG.to_string());
            }
            // first attempt: shed with a hint; second: serve it
            if attempts.fetch_add(1, Ordering::SeqCst) == 0 {
                Some(
                    r#"{"id":7,"ok":false,"error":"overloaded","retry_after_ms":40}"#
                        .to_string(),
                )
            } else {
                Some(r#"{"id":7,"ok":true,"nll":2.0,"tokens":2.0}"#.to_string())
            }
        })
    };
    let handle = router_over(vec![stub.addr.clone()], test_cfg());
    let mut c = Client::connect(handle.addr);
    let t0 = Instant::now();
    let r = c.roundtrip(r#"{"id":7,"op":"score","text":"a b"}"#);
    // the shed never reaches the client — only the retried success does
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    assert_eq!(r.get("nll").unwrap().as_f64(), Some(2.0));
    assert!(
        t0.elapsed() >= Duration::from_millis(35),
        "retry_after_ms hint not honored: answered in {:?}",
        t0.elapsed()
    );
    assert_eq!(attempts.load(Ordering::SeqCst), 2, "exactly one retry");

    let stats = handle.shutdown();
    assert_eq!(stat(&stats, "hinted_backoffs") as usize, 1, "{stats}");
    stub.stop.store(true, Ordering::SeqCst);
}

#[test]
fn drain_resume_cycle_keeps_serving_and_syncs_direct_drains() {
    let (s0, s1) = (
        mock_server(4, Duration::from_millis(5)),
        mock_server(4, Duration::from_millis(5)),
    );
    let cfg = test_cfg();
    let health_interval = cfg.health_interval;
    let handle = router_over(vec![s0.addr.to_string(), s1.addr.to_string()], cfg);
    let mut c = Client::connect(handle.addr);

    // drain replica 0 through the router: it leaves rotation healthy
    let r = c.roundtrip(r#"{"id":1,"op":"drain","replica":0}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    assert_eq!(
        r.get("reply").unwrap().get("drained"),
        Some(&Json::Bool(true)),
        "{r}"
    );
    assert_eq!(handle.pool().healthy_count(), 1);

    // traffic keeps flowing on the survivor — zero errors during the
    // rolling-restart window
    for i in 0..10 {
        let r = c.roundtrip(&format!(r#"{{"id":{i},"op":"score","text":"w"}}"#));
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    }

    // resume: back in rotation
    let r = c.roundtrip(r#"{"id":2,"op":"resume","replica":0}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    assert_eq!(handle.pool().healthy_count(), 2);

    // a drain issued DIRECTLY on a replica (not via the router) is
    // picked up from the pong's draining flag by the prober...
    let mut direct = Client::connect(s1.addr);
    let r = direct.roundtrip(r#"{"id":3,"op":"drain"}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    wait_until("prober to see the direct drain", health_interval * 40, || {
        handle.pool().healthy_count() == 1
    });
    // ...and so is the direct resume
    let r = direct.roundtrip(r#"{"id":4,"op":"resume"}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    wait_until("prober to see the direct resume", health_interval * 40, || {
        handle.pool().healthy_count() == 2
    });

    handle.shutdown();
    s0.shutdown();
    s1.shutdown();
}

#[test]
fn chaos_proxy_outage_fails_generates_fast_and_scores_over() {
    // one mock replica behind the chaos proxy; slow batching window so a
    // request is reliably in flight when the link is cut
    let server = mock_server(64, Duration::from_millis(200));
    let plan = ChaosPlan::new();
    let proxy = ChaosProxy::spawn(&server.addr.to_string(), plan.clone()).expect("proxy");
    let mut cfg = test_cfg();
    // this test is about retry/failover, not the breaker: keep it shut
    cfg.breaker.fail_threshold = 1000;
    cfg.retries = 10;
    let handle = router_over(vec![proxy.addr.to_string()], cfg);
    let mut c = Client::connect(handle.addr);

    // baseline through the fault-free proxy
    let r = c.roundtrip(r#"{"id":0,"op":"score","text":"warm"}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");

    // cut the link while a generate is in flight: fail-fast clean error,
    // no silent duplicate execution
    c.send(r#"{"id":"g","op":"generate","prompt":"a b","max_tokens":4}"#);
    std::thread::sleep(Duration::from_millis(60));
    plan.set_down(true);
    let r = c.recv();
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "{r}");
    assert_eq!(r.get("id").unwrap().as_str(), Some("g"));
    assert!(
        r.get("error").unwrap().as_str().unwrap().contains("mid-generate"),
        "{r}"
    );

    // restore the link; an idempotent score sent into the outage window
    // survives via paced retries once the link is back
    std::thread::sleep(Duration::from_millis(30));
    plan.set_down(false);
    let r = c.roundtrip(r#"{"id":"s","op":"score","text":"back again"}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");

    // a score cut *mid-flight* fails over (same replica after recovery)
    c.send(r#"{"id":"s2","op":"score","text":"cut me"}"#);
    std::thread::sleep(Duration::from_millis(60));
    plan.set_down(true);
    std::thread::sleep(Duration::from_millis(100));
    plan.set_down(false);
    let r = c.recv();
    assert_eq!(
        r.get("ok"),
        Some(&Json::Bool(true)),
        "idempotent score must survive a mid-flight cut: {r}"
    );
    assert_eq!(r.get("id").unwrap().as_str(), Some("s2"));

    let stats = handle.shutdown();
    assert!(stat(&stats, "failovers") >= 1.0, "{stats}");
    assert!(stat(&stats, "retries") >= 1.0, "{stats}");
    proxy.stop();
    server.shutdown();
}

/// The headline chaos test: two supervised `repro serve --mock` child
/// processes, SIGKILL one under open-loop load. Every idempotent score
/// must be answered successfully (failover), the killed replica must be
/// restarted by the supervisor, and the breaker must re-admit it via
/// half-open probes.
#[test]
fn sigkill_failover_loses_no_scores_and_readmits_the_replica() {
    let spec = SpawnSpec {
        bin: std::path::PathBuf::from(env!("CARGO_BIN_EXE_repro")),
        serve_args: vec!["--mock".into()],
        count: 2,
        restart_base: Duration::from_millis(100),
        ..SpawnSpec::default()
    };
    let sup = Supervisor::spawn(spec).expect("spawn replicas");
    let addrs = sup.addrs();
    let handle = Router::spawn(test_cfg(), addrs, Some(sup)).expect("spawn router");
    let c = Client::connect(handle.addr);
    let Client { mut reader, mut writer } = c;

    // reader thread: collect every reply (replies interleave across
    // replicas, so order is not guaranteed — match by id)
    let n = 120;
    let collector = std::thread::spawn(move || {
        let mut answered: HashMap<usize, Json> = HashMap::new();
        let mut line = String::new();
        while answered.len() < n {
            line.clear();
            let got = reader.read_line(&mut line).expect("recv under load");
            assert!(got > 0, "router closed the connection under load");
            let r = Json::parse(line.trim()).expect("json");
            let id = r.get("id").unwrap().as_usize().unwrap();
            assert!(
                r.get("ok") == Some(&Json::Bool(true)),
                "score {id} lost during failover: {r}"
            );
            assert!(answered.insert(id, r).is_none(), "id {id} answered twice");
        }
        answered
    });

    // open-loop sender: keeps the load coming straight through the kill
    for i in 0..n {
        writeln!(writer, r#"{{"id":{i},"op":"score","text":"w{i} x"}}"#).expect("send");
        writer.flush().expect("flush");
        if i == 30 {
            handle.kill_replica(0).expect("kill replica 0");
        }
        std::thread::sleep(Duration::from_millis(3));
    }
    let answered = collector.join().expect("collector");
    assert_eq!(answered.len(), n, "every score answered exactly once");
    let mut c = Client {
        reader: BufReader::new(writer.try_clone().expect("clone")),
        writer,
    };

    // the supervisor restarts the victim and the breaker re-admits it
    wait_until(
        "killed replica to restart and re-enter rotation",
        Duration::from_secs(15),
        || handle.pool().healthy_count() == 2,
    );

    // traffic uses both replicas again
    for i in 0..10 {
        let r = c.roundtrip(&format!(r#"{{"id":"post{i}","op":"score","text":"y"}}"#));
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    }

    let stats = handle.shutdown();
    assert!(
        stat(&stats, "breaker_opens") >= 1.0,
        "the kill must open the breaker: {stats}"
    );
    assert!(
        stat(&stats, "breaker_closes") >= 1.0,
        "the restart must close it again: {stats}"
    );
}

#[test]
fn rendezvous_placement_is_stable_uniform_and_minimally_disruptive() {
    spectron::util::prop::check("rendezvous_placement", |rng| {
        let n = 2 + rng.below(6) as usize; // 2..=7 replicas
        let candidates: Vec<usize> = (0..n).collect();
        for _ in 0..40 {
            let key = format!("k{}", rng.next_u64());
            let a = rendezvous_pick(&key, &candidates)
                .ok_or("pick returned None on a non-empty set")?;
            if rendezvous_pick(&key, &candidates) != Some(a) {
                return Err(format!("pick not deterministic for {key}"));
            }
            // removing a replica the key is NOT on never moves the key
            let other = rng.below(n as u64) as usize;
            if other != a {
                let without: Vec<usize> =
                    candidates.iter().copied().filter(|&c| c != other).collect();
                if rendezvous_pick(&key, &without) != Some(a) {
                    return Err(format!(
                        "removing replica {other} moved key {key} off replica {a}"
                    ));
                }
            }
            // removing its own replica rehashes it to a survivor
            let without_a: Vec<usize> =
                candidates.iter().copied().filter(|&c| c != a).collect();
            match rendezvous_pick(&key, &without_a) {
                Some(b) if b != a => {}
                other => return Err(format!("bad rehash for {key}: {other:?}")),
            }
        }
        Ok(())
    });

    // balance: deterministic hash, so fixed generous bounds can't flake
    let candidates: Vec<usize> = (0..4).collect();
    let mut counts = [0usize; 4];
    for i in 0..2000 {
        counts[rendezvous_pick(&format!("session-{i}"), &candidates).unwrap()] += 1;
    }
    for (i, &c) in counts.iter().enumerate() {
        assert!(
            (250..=750).contains(&c),
            "replica {i} got {c}/2000 keys (expected ~500): {counts:?}"
        );
    }
}
