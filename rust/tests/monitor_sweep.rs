//! Cross-layer tests for the crash-safe sweep orchestrator
//! (DESIGN.md §Monitoring and sweeps): registry skip/resume semantics,
//! config-hash invalidation, and the durable per-run trails — all on the
//! artifact-free native backend, so the suite runs in any container.

use std::sync::Arc;

use spectron::config::{Registry, RunCfg};
use spectron::data::bpe::Bpe;
use spectron::data::corpus::{Corpus, CorpusCfg};
use spectron::data::dataset::{Dataset, Split};
use spectron::monitor::sweep::{
    self, config_hash, hash_hex, ExecBackend, GridSpec, RunManifest, RunSpec, SweepOpts,
};
use spectron::monitor::{GuardKind, Policy};
use spectron::runtime::NativeBackend;
use spectron::train::checkpoint::RollingCheckpoints;
use spectron::train::Trainer;

const VARIANT: &str = "fact-z0-spectron";

fn tiny_dataset(vocab: usize) -> Arc<Dataset> {
    let corpus = Corpus::new(CorpusCfg::default());
    let sample = corpus.text_range(1, 120);
    let bpe = Bpe::train(&sample, vocab);
    Arc::new(Dataset::build_with(&corpus, &bpe, 500, 128))
}

fn run_cfg(steps: usize) -> RunCfg {
    RunCfg {
        total_steps: steps,
        base_lr: 0.01,
        weight_decay: 0.01,
        warmup_frac: 0.05,
        seed: 0,
        read_interval: 2,
    }
}

fn grid(name: &str, steps: &[usize]) -> GridSpec {
    GridSpec {
        name: name.to_string(),
        docs: 400,
        guards: vec![GuardKind::LossSpike],
        policy: Policy::Log,
        runs: steps
            .iter()
            .map(|&s| RunSpec {
                id: format!("z0-s{s}"),
                variant: VARIANT.into(),
                run: run_cfg(s),
            })
            .collect(),
    }
}

fn native_opts(workers: usize, max_runs: Option<usize>) -> SweepOpts {
    SweepOpts { workers, max_runs, backend: ExecBackend::Native, ..SweepOpts::default() }
}

fn cleanup(name: &str) {
    std::fs::remove_dir_all(sweep::registry_root(name)).ok();
}

/// The headline property: kill the sweep mid-grid (simulated by
/// `max_runs`), rerun, and finished runs are skipped — never retrained —
/// while the registry keeps a complete durable trail per run.
#[test]
fn sweep_is_crash_safe_and_incremental() {
    let name = format!("itest-incr-{}", std::process::id());
    cleanup(&name);
    let reg = Registry::load().unwrap();
    let ds = tiny_dataset(reg.variant(VARIANT).unwrap().model.vocab);
    let g = grid(&name, &[4, 6]);

    // session 1 "crashes" after one run
    let s1 = sweep::run_sweep(&g, &reg, &ds, &native_opts(1, Some(1))).unwrap();
    assert_eq!((s1.executed, s1.skipped, s1.failed), (1, 0, 0));

    // session 2 finishes only the unfinished run
    let s2 = sweep::run_sweep(&g, &reg, &ds, &native_opts(2, None)).unwrap();
    assert_eq!((s2.executed, s2.skipped, s2.failed), (1, 1, 0));

    // session 3 is a no-op: everything done, nothing retrains
    let s3 = sweep::run_sweep(&g, &reg, &ds, &native_opts(2, None)).unwrap();
    assert_eq!((s3.executed, s3.skipped, s3.failed), (0, 2, 0));

    let runs = sweep::report(&name).unwrap();
    assert_eq!(runs.len(), 2);
    for m in &runs {
        assert_eq!(m.status, "done", "{}", m.id);
        assert_eq!(m.steps_done, m.total_steps, "{}", m.id);
        assert!(m.final_loss.is_finite(), "{}", m.id);
        let dir = sweep::registry_root(&name).join("runs").join(&m.id);
        assert!(dir.join("manifest.json").exists());
        assert!(dir.join("metrics.jsonl").exists(), "{}: metrics trail", m.id);
        assert!(dir.join("monitor.json").exists(), "{}: monitor state", m.id);
        assert!(
            std::fs::read_dir(dir.join("ckpts")).unwrap().count() > 0,
            "{}: rolling checkpoints",
            m.id
        );
    }
    cleanup(&name);
}

/// A run left `running` with a rolling checkpoint (what a killed process
/// leaves behind) resumes from that checkpoint instead of restarting,
/// and finishes with the correct step count.
#[test]
fn interrupted_run_resumes_from_its_checkpoint() {
    let name = format!("itest-resume-{}", std::process::id());
    cleanup(&name);
    let reg = Registry::load().unwrap();
    let v = reg.variant(VARIANT).unwrap().clone();
    let ds = tiny_dataset(v.model.vocab);
    let g = grid(&name, &[6]);
    let spec = &g.runs[0];
    let dir = sweep::registry_root(&name).join("runs").join(&spec.id);

    // fabricate the crash site: 3 steps trained, checkpointed, manifest
    // still "running" under the current config hash
    let mut trainer =
        Trainer::with_backend(Box::new(NativeBackend::new(&v).unwrap()), &v, spec.run.clone())
            .unwrap();
    let mut batches = ds.batches(Split::Train, v.batch, spec.run.seed);
    trainer.train(&mut batches, 3).unwrap();
    let state = trainer.state_vec().unwrap();
    RollingCheckpoints::new(dir.join("ckpts"), VARIANT, 3)
        .unwrap()
        .save(3, &state)
        .unwrap();
    let hash = hash_hex(config_hash(&v, &spec.run, g.docs));
    let mut m = RunManifest::fresh(&spec.id, VARIANT, &hash, spec.run.total_steps);
    m.status = "running".into();
    m.steps_done = 3;
    m.save(&dir).unwrap();

    let s = sweep::run_sweep(&g, &reg, &ds, &native_opts(1, None)).unwrap();
    assert_eq!((s.executed, s.failed), (1, 0));
    assert_eq!(s.resumed, 1, "the run must resume, not restart");

    let m = RunManifest::load(&dir).unwrap().unwrap();
    assert_eq!(m.status, "done");
    assert_eq!(m.steps_done, 6);
    assert_eq!(m.resumed_from, Some(3));
    cleanup(&name);
}

/// Editing a run's config (here: weight decay, which is not part of the
/// run id) changes its hash: the registry retrains instead of silently
/// reusing the stale result, and the manifest re-keys to the new hash.
#[test]
fn config_change_invalidates_finished_run() {
    let name = format!("itest-inval-{}", std::process::id());
    cleanup(&name);
    let reg = Registry::load().unwrap();
    let ds = tiny_dataset(reg.variant(VARIANT).unwrap().model.vocab);

    let g1 = grid(&name, &[4]);
    let s1 = sweep::run_sweep(&g1, &reg, &ds, &native_opts(1, None)).unwrap();
    assert_eq!((s1.executed, s1.skipped), (1, 0));

    // same id, different config
    let mut g2 = grid(&name, &[4]);
    g2.runs[0].run.weight_decay = 0.05;
    let s2 = sweep::run_sweep(&g2, &reg, &ds, &native_opts(1, None)).unwrap();
    assert_eq!(
        (s2.executed, s2.skipped),
        (1, 0),
        "a config edit must retrain, not reuse"
    );

    let dir = sweep::registry_root(&name).join("runs").join(&g2.runs[0].id);
    let m = RunManifest::load(&dir).unwrap().unwrap();
    let v = reg.variant(VARIANT).unwrap();
    assert_eq!(m.cfg, hash_hex(config_hash(v, &g2.runs[0].run, g2.docs)));
    assert_eq!(m.status, "done");

    // and an unchanged rerun of the edited grid is again a no-op
    let s3 = sweep::run_sweep(&g2, &reg, &ds, &native_opts(1, None)).unwrap();
    assert_eq!((s3.executed, s3.skipped), (0, 1));
    cleanup(&name);
}
