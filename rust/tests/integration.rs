//! Cross-layer integration tests, parameterized over execution backends
//! (DESIGN.md §Backends).
//!
//! Every test runs UNCONDITIONALLY on the native backend — no artifacts,
//! no Python, no PJRT involved — so the suite verifies the trainer,
//! coordinator, eval and serve layers in any container. When `make
//! artifacts` has been run, each test additionally executes its PJRT
//! parameterization (the real AOT-compiled HLO), and the cross-backend
//! agreement test pins the two implementations against each other.
//!
//! PJRT tests are grouped into a few large functions so that each
//! compiled program is reused within a test thread (the PJRT runtime is
//! thread-local); small z0 programs keep compile times low.

use std::sync::Arc;

use spectron::config::{Registry, RunCfg, VariantCfg};
use spectron::coordinator::{DataParallelSim, GradAccumulator};
use spectron::data::bpe::Bpe;
use spectron::data::corpus::{Corpus, CorpusCfg};
use spectron::data::dataset::{Dataset, Split};
use spectron::data::prefetch::Prefetcher;
use spectron::eval::{downstream, perplexity, Evaluator};
use spectron::linalg;
use spectron::monitor::{
    Directive, GuardKind, Monitor, MonitorCfg, Policy, Signal, SpikeInjector, StepObserver,
};
use spectron::runtime::backend::{Backend, BackendKind};
use spectron::runtime::state as slots;
use spectron::runtime::{layout, ArtifactIndex, NativeBackend, PjrtBackend, Runtime, StateHost};
use spectron::train::schedule::Schedule;
use spectron::train::{checkpoint, MetricsLog, Record, Trainer};
use spectron::util::rng::Pcg64;

const VARIANT: &str = "fact-z0-spectron";

fn artifacts() -> Option<ArtifactIndex> {
    let root = ArtifactIndex::default_root();
    if root.join("index.json").exists() {
        Some(ArtifactIndex::load(&root).unwrap())
    } else {
        eprintln!("artifacts not built: running the native parameterization only");
        None
    }
}

/// The backends this checkout can run: native always, pjrt when built.
fn backends() -> Vec<BackendKind> {
    let mut v = vec![BackendKind::Native];
    if artifacts().is_some() {
        v.push(BackendKind::Pjrt);
    }
    v
}

fn make_backend(kind: BackendKind, v: &VariantCfg) -> Box<dyn Backend> {
    match kind {
        BackendKind::Native => Box::new(NativeBackend::new(v).unwrap()),
        BackendKind::Pjrt => {
            let idx = artifacts().expect("pjrt parameterization needs artifacts");
            let rt = Runtime::shared().unwrap();
            Box::new(PjrtBackend::new(&rt, &idx, &v.name).unwrap())
        }
    }
}

fn tiny_dataset(vocab: usize) -> Arc<Dataset> {
    let corpus = Corpus::new(CorpusCfg::default());
    let sample = corpus.text_range(1, 150);
    let bpe = Bpe::train(&sample, vocab);
    Arc::new(Dataset::build_with(&corpus, &bpe, 800, 128))
}

fn run_cfg(steps: usize) -> RunCfg {
    RunCfg {
        total_steps: steps,
        base_lr: 0.01,
        weight_decay: 0.01,
        warmup_frac: 0.05,
        seed: 0,
        read_interval: 5,
    }
}

fn z0(reg: &Registry) -> &VariantCfg {
    reg.variant(VARIANT).unwrap()
}

/// init -> step loop -> ring/telemetry/schedule/ckpt/resume, per backend.
#[test]
fn train_loop_end_to_end() {
    let reg = Registry::load().unwrap();
    let v = z0(&reg);
    let ds = tiny_dataset(v.model.vocab);
    for kind in backends() {
        let run = run_cfg(30);
        let mut trainer =
            Trainer::with_backend(make_backend(kind, v), v, run.clone()).unwrap();
        assert_eq!(trainer.state().step(), 0);
        let mut batches = ds.batches(Split::Train, v.batch, 0);
        let res = trainer.train(&mut batches, 30).unwrap();

        // loss curve: starts near ln(vocab), strictly recorded per step
        assert_eq!(res.losses.len(), 30, "{kind}");
        assert!(res.losses.windows(2).all(|w| w[0].0 + 1 == w[1].0));
        let first = res.losses[0].1 as f64;
        assert!((first - (v.model.vocab as f64).ln()).abs() < 1.2, "{kind}: {first}");
        assert!(
            res.final_loss < first - 0.5,
            "{kind}: no learning: {first} -> {}",
            res.final_loss
        );
        assert!(!res.diverged);

        // header: schedule mirror agrees with the in-graph lr
        let sched = Schedule {
            total_steps: run.total_steps,
            base_lr: run.base_lr,
            warmup_frac: run.warmup_frac,
        };
        let host_lr = sched.lr_at(trainer.state().step() - 1);
        let graph_lr = trainer.state().lr() as f64;
        assert!(
            (host_lr - graph_lr).abs() / host_lr < 1e-4,
            "{kind}: lr mirror drift: host {host_lr} vs graph {graph_lr}"
        );
        assert_eq!(
            trainer.state().tokens_seen(),
            (30 * v.batch * v.model.seq_len) as f64
        );

        // spectral telemetry: spectron's bound ||dW||_2 <= ~lr (Eq. 11)
        let tel = trainer.state().telemetry();
        assert!(tel[0] > 0.05, "{kind}: w_spec {tel:?}");
        assert!(
            tel[1] > 0.0 && (tel[1] as f64) <= 1.5 * graph_lr,
            "{kind}: dw_spec {tel:?}"
        );
        assert!(tel[5] > 0.0 && tel[5] < trainer.state().lr(), "{kind}: rho {tel:?}");

        // telemetry cross-check: host power iteration on the state's
        // factor views reproduces sigma_a within power-iter tolerance
        let manifest = trainer.manifest.clone();
        let host = trainer.sync().unwrap().clone();
        let lyr = manifest.layers / 2;
        let a = host.tensor(&manifest, "attn_o_a").unwrap();
        let spec_a = manifest.tensor("attn_o_a").unwrap();
        let (m, r) = (spec_a.shape[1], spec_a.shape[2]);
        let a_mat = linalg::Mat::from_f32(m, r, &a[lyr * m * r..(lyr + 1) * m * r]);
        let mut rng = Pcg64::new(1);
        let sigma_host = linalg::spectral_norm(&a_mat, 60, &mut rng);
        let sigma_graph = tel[3] as f64;
        assert!(
            (sigma_host - sigma_graph).abs() / sigma_host < 0.05,
            "{kind}: sigma_a: host {sigma_host} vs graph {sigma_graph}"
        );

        // checkpoint -> resume continues from the same step and keeps
        // learning
        let ck = std::env::temp_dir().join(format!(
            "spectron-int-{kind}-{}.ckpt",
            std::process::id()
        ));
        let state = trainer.state_vec().unwrap();
        checkpoint::save(&ck, VARIANT, &state).unwrap();
        let (ck_variant, loaded) = checkpoint::load(&ck).unwrap();
        assert_eq!(ck_variant, VARIANT);
        assert_eq!(loaded, state);
        let mut resumed =
            Trainer::from_state_backend(make_backend(kind, v), v, run.clone(), loaded)
                .unwrap();
        assert_eq!(resumed.state().step(), 30);
        let res2 = resumed.train(&mut batches, 10).unwrap();
        assert_eq!(resumed.state().step(), 40);
        assert!(res2.losses.first().unwrap().0 == 30);
        std::fs::remove_file(&ck).ok();
    }
}

/// eval program: perplexity consistency + span restriction + downstream.
#[test]
fn eval_programs_end_to_end() {
    let reg = Registry::load().unwrap();
    let v = z0(&reg);
    let corpus = Corpus::new(CorpusCfg::default());
    let sample = corpus.text_range(1, 150);
    let bpe = Bpe::train(&sample, v.model.vocab);
    let ds = Arc::new(Dataset::build_with(&corpus, &bpe, 800, 128));
    for kind in backends() {
        let mut trainer =
            Trainer::with_backend(make_backend(kind, v), v, run_cfg(25)).unwrap();
        let mut batches = ds.batches(Split::Train, v.batch, 0);
        trainer.train(&mut batches, 25).unwrap();
        let state = trainer.state_vec().unwrap();
        let manifest = trainer.manifest.clone();
        let ev = Evaluator::with_backend(make_backend(kind, v));
        let prefix = &state[..manifest.params_end];

        // perplexity far below uniform after training
        let ppl = perplexity::perplexity(&ev, prefix, &ds, 10).unwrap();
        assert!(ppl.ppl < v.model.vocab as f64 * 0.9, "{kind}: ppl {}", ppl.ppl);
        assert!(ppl.tokens > 0.0);

        // an UNTRAINED model scores ~uniform — eval is actually using
        // the params it was handed
        let t2 = Trainer::with_backend(make_backend(kind, v), v, run_cfg(25)).unwrap();
        let fresh = t2.state().data.clone();
        let ppl0 =
            perplexity::perplexity(&ev, &fresh[..manifest.params_end], &ds, 4).unwrap();
        assert!(
            (ppl0.ppl.ln() - (v.model.vocab as f64).ln()).abs() < 1.2,
            "{kind}: fresh ppl {}",
            ppl0.ppl
        );
        assert!(ppl.ppl < ppl0.ppl * 0.8);

        // downstream suite runs and returns sane accuracies
        let suite = downstream::run_suite(&ev, prefix, &bpe, &corpus, 24, 7).unwrap();
        assert_eq!(suite.len(), 3);
        for t in &suite {
            assert!(t.accuracy >= 0.0 && t.accuracy <= 1.0);
            assert_eq!(t.n_items, 24);
        }
    }
}

/// grad/apply path: equivalence with the fused step, accumulation, and
/// the simulated data-parallel coordinator.
#[test]
fn coordinator_end_to_end() {
    let reg = Registry::load().unwrap();
    let v = z0(&reg);
    let ds = tiny_dataset(v.model.vocab);
    for kind in backends() {
        // (a) grad+apply == fused step on identical batches. Natively the
        // fused step IS grad∘apply, so the match is exact; under PJRT the
        // two programs fuse differently, so f32 rounding diverges and the
        // Newton-Schulz polynomial amplifies it a little each step
        // (~1e-4/step is numerical, not semantic).
        let run = run_cfg(10);
        let mut fused =
            Trainer::with_backend(make_backend(kind, v), v, run.clone()).unwrap();
        let mut acc =
            GradAccumulator::with_backend(make_backend(kind, v), run.clone()).unwrap();
        let mut b1 = ds.batches(Split::Train, v.batch, 0);
        let mut b2 = ds.batches(Split::Train, v.batch, 0);
        for _ in 0..3 {
            fused.train(&mut b1, 1).unwrap();
            acc.step(&mut b2, 1).unwrap();
        }
        let s_fused = fused.state_vec().unwrap();
        let s_acc = acc.state().unwrap().data;
        let manifest = acc.manifest().clone();
        let mut max_diff = 0f32;
        for i in manifest.hdr..manifest.state_len {
            max_diff = max_diff.max((s_fused[i] - s_acc[i]).abs());
        }
        match kind {
            BackendKind::Native => {
                assert_eq!(max_diff, 0.0, "native fused vs split must be exact")
            }
            BackendKind::Pjrt => {
                assert!(max_diff < 3e-3, "fused vs grad/apply drift {max_diff}")
            }
        }

        // (b) accumulation over k microbatches trains stably
        let mut acc2 =
            GradAccumulator::with_backend(make_backend(kind, v), run_cfg(10)).unwrap();
        let mut b3 = ds.batches(Split::Train, v.batch, 1);
        let mut losses = Vec::new();
        for _ in 0..6 {
            losses.push(acc2.step(&mut b3, 3).unwrap());
        }
        assert!(losses.last().unwrap() < losses.first().unwrap(), "{kind}");

        // (c) DP sim: replicas share the state and the loss goes down;
        // all-reduce keeps the apply path identical to a global batch
        let mut dp = match kind {
            BackendKind::Native => {
                DataParallelSim::native(v, run_cfg(10), &ds, 3, false).unwrap()
            }
            BackendKind::Pjrt => {
                let idx = artifacts().unwrap();
                let rt = Runtime::shared().unwrap();
                DataParallelSim::new(&rt, &idx, v, run_cfg(10), &ds, 3).unwrap()
            }
        };
        assert_eq!(dp.n_workers(), 3);
        let mut first = f64::NAN;
        let mut last = f64::NAN;
        for s in 0..6 {
            let stats = dp.step().unwrap();
            assert_eq!(stats.worker_losses.len(), 3);
            assert!(stats.grad_norm.is_finite());
            if s == 0 {
                first = stats.mean_loss;
            }
            last = stats.mean_loss;
        }
        assert!(last < first, "{kind}: dp did not progress: {first} -> {last}");
        let st = dp.state().unwrap();
        assert_eq!(st.step(), 6);
    }
}

/// Pipelined hot path: training through the async prefetch ring is
/// bit-identical to training through the synchronous iterator (the
/// prefetcher only moves *when* a batch is packed, never what's in it or
/// how it is handed to the backend).
#[test]
fn prefetched_training_matches_sync() {
    let reg = Registry::load().unwrap();
    let v = z0(&reg);
    let ds = tiny_dataset(v.model.vocab);
    for kind in backends() {
        let mut t_sync =
            Trainer::with_backend(make_backend(kind, v), v, run_cfg(12)).unwrap();
        let mut batches = ds.batches(Split::Train, v.batch, 3);
        t_sync.train(&mut batches, 12).unwrap();

        let mut t_pf =
            Trainer::with_backend(make_backend(kind, v), v, run_cfg(12)).unwrap();
        let mut pf = Prefetcher::new(ds.clone(), Split::Train, v.batch, 3);
        t_pf.train(&mut pf, 12).unwrap();

        let a = t_sync.state_vec().unwrap();
        let b = t_pf.state_vec().unwrap();
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{kind}: state diverged at slot {i}");
        }
    }
}

/// Threaded DP (persistent per-worker backends) is bit-identical to the
/// sequential reference: same reduced gradients, same state, for every
/// tested worker count, on both backends.
#[test]
fn parallel_dp_matches_sequential() {
    let reg = Registry::load().unwrap();
    let v = z0(&reg);
    let ds = tiny_dataset(v.model.vocab);
    for kind in backends() {
        let counts: &[usize] = match kind {
            BackendKind::Native => &[1, 2, 3],
            BackendKind::Pjrt => &[1, 2, 3, 8],
        };
        for &n in counts {
            let (mut seq, mut par) = match kind {
                BackendKind::Native => (
                    DataParallelSim::native(v, run_cfg(6), &ds, n, false).unwrap(),
                    DataParallelSim::native(v, run_cfg(6), &ds, n, true).unwrap(),
                ),
                BackendKind::Pjrt => {
                    let idx = artifacts().unwrap();
                    let rt = Runtime::shared().unwrap();
                    (
                        DataParallelSim::new(&rt, &idx, v, run_cfg(6), &ds, n).unwrap(),
                        DataParallelSim::new_threaded(&rt, &idx, v, run_cfg(6), &ds, n)
                            .unwrap(),
                    )
                }
            };
            assert!(!seq.is_threaded() && par.is_threaded());
            for s in 0..3 {
                let a = seq.step().unwrap();
                let b = par.step().unwrap();
                assert_eq!(a.worker_losses.len(), n);
                let la: Vec<u64> = a.worker_losses.iter().map(|x| x.to_bits()).collect();
                let lb: Vec<u64> = b.worker_losses.iter().map(|x| x.to_bits()).collect();
                assert_eq!(la, lb, "{kind}: worker losses, n={n} step {s}");
                let ga: Vec<u32> =
                    seq.last_reduced_grad().iter().map(|x| x.to_bits()).collect();
                let gb: Vec<u32> =
                    par.last_reduced_grad().iter().map(|x| x.to_bits()).collect();
                assert_eq!(ga.len(), gb.len());
                assert!(ga == gb, "{kind}: reduced grad bits differ, n={n} step {s}");
            }
            let sa = seq.state().unwrap().data;
            let sb = par.state().unwrap().data;
            for (i, (x, y)) in sa.iter().zip(&sb).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "{kind}: state slot {i}, n={n}");
            }
            assert_eq!(seq.state().unwrap().step(), 3);
        }
    }
}

/// Tensor-core acceptance (DESIGN.md §Native tensor core;
/// docs/adr/005-parallel-tensor-core.md): a MONITORED native train run
/// at any `--threads` value is bit-identical (state vector `to_bits`)
/// to the serial run — the pool only reassigns work, never arithmetic.
#[test]
fn threaded_native_training_is_bit_identical() {
    let reg = Registry::load().unwrap();
    let v = z0(&reg);
    let ds = tiny_dataset(v.model.vocab);
    let monitor_cfg = || MonitorCfg {
        guards: vec![GuardKind::LossSpike, GuardKind::SpectronBound],
        policy: Policy::Log,
        ..MonitorCfg::default()
    };
    let run_at = |threads: usize| {
        let mut t = Trainer::native_with_threads(v, run_cfg(10), threads).unwrap();
        let mut batches = ds.batches(Split::Train, v.batch, 5);
        let mut monitor = Monitor::new(monitor_cfg());
        let mut metrics = MetricsLog::in_memory("thread-bits");
        let res = t.train_observed(&mut batches, 10, &mut metrics, &mut monitor).unwrap();
        assert_eq!(res.steps_done, 10, "threads {threads}: run did not complete");
        t.state_vec().unwrap()
    };
    let want = run_at(1);
    for threads in [2usize, 4, 8] {
        let got = run_at(threads);
        assert_eq!(want.len(), got.len());
        for (i, (a, b)) in want.iter().zip(&got).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "threads {threads}: state slot {i}");
        }
    }
}

/// A log-policy monitor observes without perturbing: monitored training
/// is bit-identical to unmonitored training — the observer rides the
/// readbacks the loop already performs
/// (DESIGN.md §Monitoring and sweeps).
#[test]
fn monitored_training_is_bit_identical_when_logging() {
    let reg = Registry::load().unwrap();
    let v = z0(&reg);
    let ds = tiny_dataset(v.model.vocab);
    for kind in backends() {
        let mut plain =
            Trainer::with_backend(make_backend(kind, v), v, run_cfg(14)).unwrap();
        let mut b1 = ds.batches(Split::Train, v.batch, 2);
        plain.train(&mut b1, 14).unwrap();

        let mut watched =
            Trainer::with_backend(make_backend(kind, v), v, run_cfg(14)).unwrap();
        let mut b2 = ds.batches(Split::Train, v.batch, 2);
        let mut monitor = Monitor::new(MonitorCfg {
            guards: vec![
                GuardKind::LossSpike,
                GuardKind::SpectronBound,
                GuardKind::RhoCollapse,
                GuardKind::SigmaCollapse,
            ],
            policy: Policy::Log,
            ..MonitorCfg::default()
        });
        let mut metrics = MetricsLog::in_memory("watched");
        watched.train_observed(&mut b2, 14, &mut metrics, &mut monitor).unwrap();

        assert_eq!(monitor.events_seen, 0, "{kind}: healthy run must be event-free");
        let a = plain.state_vec().unwrap();
        let b = watched.state_vec().unwrap();
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{kind}: slot {i}");
        }
    }
}

/// Records the rollback directive the monitor issues so the test can
/// compare its payload against an independent reference trajectory.
struct RollbackSpy<'m> {
    inner: &'m mut Monitor,
    rollback: Option<(usize, Vec<f32>)>,
}

impl StepObserver for RollbackSpy<'_> {
    fn observe(&mut self, host: &StateHost, rec: &Record, ring: &[(usize, f32)]) -> Directive {
        let d = self.inner.observe(host, rec, ring);
        if let Directive::Rollback { to_step, state, .. } = &d {
            self.rollback = Some((*to_step, state.clone()));
        }
        d
    }
}

/// The end-to-end stability scenario on the artifact-free native
/// backend: a non-Spectron variant with an injected gradient spike
/// triggers detection, rolls back to the last healthy checkpoint
/// bit-for-bit, resumes, and completes — while the same seed under
/// Spectron (its own spectral guards on) completes with zero events.
#[test]
fn stability_scenario_spike_rollback_and_clean_spectron() {
    let reg = Registry::load().unwrap();
    // the baseline: z0 architecture trained with plain momentum SGD (the
    // native backend builds any VariantCfg, registry entry or not)
    let mut sgd = reg.variant("fact-z0-spectron").unwrap().clone();
    sgd.name = "fact-z0-sgd-injected".into();
    sgd.optimizer = "sgd".into();
    let ds = tiny_dataset(sgd.model.vocab);
    let run = RunCfg { read_interval: 2, ..run_cfg(20) };

    // reference trajectory, no injection: pins the pre-spike state
    let mut reference =
        Trainer::with_backend(Box::new(NativeBackend::new(&sgd).unwrap()), &sgd, run.clone())
            .unwrap();
    let mut bref = ds.batches(Split::Train, sgd.batch, 0);
    reference.train(&mut bref, 12).unwrap();
    let pre_spike = reference.state_vec().unwrap();
    assert_eq!(reference.state().step(), 12);

    // injected run: gradient x1e4 on step 13 wrecks the params; the
    // huge loss lands in the ring at the step-14 readback
    let inner = Box::new(NativeBackend::new(&sgd).unwrap());
    let injector = Box::new(SpikeInjector::new(inner, 13, 1e4).unwrap());
    let mut trainer = Trainer::with_backend(injector, &sgd, run.clone()).unwrap();
    let mut monitor = Monitor::new(MonitorCfg {
        guards: vec![GuardKind::LossSpike],
        policy: Policy::Rollback { skip_batches: 0 },
        cooldown_obs: 2,
        max_interventions: 3,
        keep_ckpts: 2,
    });
    let mut spy = RollbackSpy { inner: &mut monitor, rollback: None };
    let mut batches = ds.batches(Split::Train, sgd.batch, 0);
    let mut metrics = MetricsLog::in_memory("scenario");
    let res = trainer.train_observed(&mut batches, 20, &mut metrics, &mut spy).unwrap();

    // detection fired and the rollback payload IS the pre-spike state,
    // bit for bit
    let (to_step, rolled) = spy.rollback.expect("spike must trigger a rollback");
    assert_eq!(to_step, 12, "rollback targets the last healthy readback");
    assert_eq!(rolled.len(), pre_spike.len());
    for (i, (a, b)) in rolled.iter().zip(&pre_spike).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "rollback state differs at slot {i}");
    }
    assert!(monitor.events_seen >= 1);
    assert_eq!(monitor.interventions, 1);

    // and the run then completed to its target on fresh batches
    assert!(!res.halted && !res.diverged, "run must finish after the intervention");
    assert_eq!(trainer.state().step(), 20);
    assert!(
        res.final_loss.is_finite() && res.final_loss < 8.0,
        "post-rollback loss recovered: {}",
        res.final_loss
    );
    assert!(res.steps_done > 20, "the rolled-back window re-ran");

    // the same seed under Spectron, full spectral guard set: zero events
    let spectron = reg.variant("fact-z0-spectron").unwrap();
    let mut clean = Trainer::with_backend(
        Box::new(NativeBackend::new(spectron).unwrap()),
        spectron,
        run.clone(),
    )
    .unwrap();
    let mut cmon = Monitor::new(MonitorCfg {
        guards: vec![
            GuardKind::LossSpike,
            GuardKind::SpectronBound,
            GuardKind::RhoCollapse,
            GuardKind::SigmaCollapse,
        ],
        policy: Policy::Rollback { skip_batches: 0 },
        ..MonitorCfg::default()
    });
    let mut bclean = ds.batches(Split::Train, spectron.batch, 0);
    let mut cmetrics = MetricsLog::in_memory("clean");
    let cres = clean.train_observed(&mut bclean, 20, &mut cmetrics, &mut cmon).unwrap();
    assert_eq!(cmon.events_seen, 0, "spectron must respect its own bound");
    assert_eq!(cmon.interventions, 0);
    assert!(!cres.halted && !cres.diverged);
    assert_eq!(clean.state().step(), 20);
    assert_eq!(cres.steps_done, 20, "no re-runs on the clean trajectory");
}

/// The observer hook is honored by the coordinator loops too: a halt
/// directive stops an accumulation run, and the DP coordinator applies
/// an lr cut to the replicated state every worker sees next step.
#[test]
fn coordinator_loops_honor_observer() {
    let reg = Registry::load().unwrap();
    let v = z0(&reg);
    let ds = tiny_dataset(v.model.vocab);

    // halt-on-first-observation observer
    struct HaltNow;
    impl StepObserver for HaltNow {
        fn observe(&mut self, _h: &StateHost, _r: &Record, _ring: &[(usize, f32)]) -> Directive {
            Directive::Halt { reason: "test".into() }
        }
    }
    let mut acc =
        GradAccumulator::with_backend(Box::new(NativeBackend::new(v).unwrap()), run_cfg(10))
            .unwrap();
    let mut batches = ds.batches(Split::Train, v.batch, 0);
    let (loss, sig) = acc.step_observed(&mut batches, 2, &mut HaltNow).unwrap();
    assert!(loss.is_finite());
    assert_eq!(sig, Signal::Halted);

    // lr-cut lands in the replicated state's header
    struct CutOnce {
        done: bool,
    }
    impl StepObserver for CutOnce {
        fn observe(&mut self, _h: &StateHost, _r: &Record, _ring: &[(usize, f32)]) -> Directive {
            if self.done {
                Directive::Continue
            } else {
                self.done = true;
                Directive::CutLr { factor: 0.5 }
            }
        }
    }
    let mut dp = DataParallelSim::native(v, run_cfg(10), &ds, 2, false).unwrap();
    let base_lr = dp.state().unwrap().slot(slots::BASE_LR);
    let mut cut = CutOnce { done: false };
    let (_stats, sig) = dp.step_observed(&mut cut, 0.0).unwrap();
    assert_eq!(sig, Signal::Continue);
    let after = dp.state().unwrap().slot(slots::BASE_LR);
    assert!(
        (after - base_lr * 0.5).abs() < 1e-12,
        "lr cut must halve the replicated base lr: {base_lr} -> {after}"
    );
    // and the sim keeps stepping normally afterwards
    let (_stats, sig) = dp.step_observed(&mut cut, 0.0).unwrap();
    assert_eq!(sig, Signal::Continue);
    assert_eq!(dp.state().unwrap().step(), 2);
}

/// Divergence is observed, not fatal: absurd lr on the spectron variant.
#[test]
fn divergence_detection() {
    let reg = Registry::load().unwrap();
    let v = z0(&reg);
    let ds = tiny_dataset(v.model.vocab);
    for kind in backends() {
        let run = RunCfg {
            total_steps: 40,
            base_lr: 500.0, // absurd
            weight_decay: 0.0,
            warmup_frac: 0.0,
            seed: 0,
            read_interval: 2,
        };
        let mut trainer = Trainer::with_backend(make_backend(kind, v), v, run).unwrap();
        let mut batches = ds.batches(Split::Train, v.batch, 0);
        let res = trainer.train(&mut batches, 40).unwrap();
        assert!(res.diverged, "{kind}: expected divergence at lr=500");
        assert!(res.steps_done < 40, "{kind}: should stop early");
    }
}

/// Layout contract: the native layout mirror is self-consistent for every
/// registry variant (ungated), and — with artifacts — agrees with every
/// python-emitted manifest tensor-for-tensor.
#[test]
fn header_layout_cross_check() {
    let reg = Registry::load().unwrap();
    for (name, v) in &reg.variants {
        let m = layout::build_manifest(v).unwrap();
        assert_eq!(m.hdr, slots::HDR, "{name}");
        assert_eq!(m.ring, slots::RING, "{name}");
        assert_eq!(m.ring_base, slots::RING_BASE, "{name}");
        let fake = vec![0f32; m.state_len];
        StateHost::new(fake, &m).unwrap();
    }
    let Some(idx) = artifacts() else { return };
    for name in &idx.variants {
        let m = idx.manifest(name).unwrap();
        assert_eq!(m.hdr, slots::HDR, "{name}");
        assert_eq!(m.ring, slots::RING, "{name}");
        assert_eq!(m.ring_base, slots::RING_BASE, "{name}");
        // the in-process mirror reproduces the python manifest exactly
        let v = reg.variant(name).unwrap();
        let native = layout::build_manifest(v).unwrap();
        assert_eq!(native.state_len, m.state_len, "{name}");
        assert_eq!(native.params_end, m.params_end, "{name}");
        assert_eq!(native.n_params, m.n_params, "{name}");
        assert_eq!(native.eval_key, m.eval_key, "{name}");
        assert_eq!(native.tensors.len(), m.tensors.len(), "{name}");
        for (a, b) in native.tensors.iter().zip(&m.tensors) {
            assert_eq!(a, b, "{name}");
        }
        let fake = vec![0f32; m.state_len];
        StateHost::new(fake, &m).unwrap();
    }
}

/// Cross-backend agreement (artifact-gated): from ONE shared initial
/// state and identical batches, the native interpreter and the compiled
/// HLO must produce the same gradients (tight, single step) and the same
/// loss trajectory (within a tolerance that grows with compounding f32
/// divergence) — for a spectron variant and a baseline optimizer, per
/// the tolerance policy in DESIGN.md §Backends.
#[test]
fn cross_backend_agreement() {
    let Some(idx) = artifacts() else { return };
    let reg = Registry::load().unwrap();
    let rt = Runtime::shared().unwrap();

    // (a) one-step gradient agreement on the split path (z0 ships grad)
    {
        let v = z0(&reg);
        let ds = tiny_dataset(v.model.vocab);
        let mut pjrt: Box<dyn Backend> = Box::new(PjrtBackend::new(&rt, &idx, &v.name).unwrap());
        let mut native: Box<dyn Backend> = Box::new(NativeBackend::new(v).unwrap());
        let knobs = [10.0, 0.01, 0.01, 0.05, 0.0, 0.0, 0.0, 0.0];
        let s0_buf = pjrt.init(0, &knobs).unwrap();
        let s0 = pjrt.download(&s0_buf).unwrap();
        let mut batches = ds.batches(Split::Train, v.batch, 0);
        let toks = batches.next_batch();
        let gp = pjrt.grad(&s0_buf, &toks).unwrap();
        let ns_buf = native.upload_state(&s0).unwrap();
        let gn = native.grad(&ns_buf, &toks).unwrap();
        assert_eq!(gp.len(), gn.len());
        assert!(
            (gp[0] as f64 - gn[0] as f64).abs() < 2e-3,
            "loss: pjrt {} vs native {}",
            gp[0],
            gn[0]
        );
        let (mut dot, mut np, mut nn) = (0f64, 0f64, 0f64);
        for (a, b) in gp[1..].iter().zip(&gn[1..]) {
            dot += (*a as f64) * (*b as f64);
            np += (*a as f64).powi(2);
            nn += (*b as f64).powi(2);
        }
        let cos = dot / (np.sqrt() * nn.sqrt());
        assert!(cos > 0.999, "gradient cosine {cos}");
        let rel = (np.sqrt() - nn.sqrt()).abs() / np.sqrt();
        assert!(rel < 0.01, "gradient norm rel diff {rel}");
    }

    // (b) loss-trajectory agreement for one spectron variant and one
    // baseline optimizer on the fused step
    for name in [VARIANT, "fact-s-sgd"] {
        let v = reg.variant(name).unwrap();
        let ds = tiny_dataset(v.model.vocab);
        let run = RunCfg { read_interval: 1, ..run_cfg(6) };
        let mut t_pjrt = Trainer::new(&rt, &idx, v, run.clone()).unwrap();
        let s0 = t_pjrt.state_vec().unwrap();
        let mut t_native = Trainer::from_state_backend(
            Box::new(NativeBackend::new(v).unwrap()),
            v,
            run.clone(),
            s0,
        )
        .unwrap();
        let mut bp = ds.batches(Split::Train, v.batch, 0);
        let mut bn = ds.batches(Split::Train, v.batch, 0);
        let rp = t_pjrt.train(&mut bp, 5).unwrap();
        let rn = t_native.train(&mut bn, 5).unwrap();
        assert_eq!(rp.losses.len(), rn.losses.len(), "{name}");
        for (i, ((sa, la), (sb, lb))) in rp.losses.iter().zip(&rn.losses).enumerate() {
            assert_eq!(sa, sb);
            // one f32-vs-f64 step differs at ~1e-3; divergence compounds
            // roughly geometrically, so the band doubles per step
            let tol = 0.03 * f64::powi(2.0, i as i32);
            assert!(
                (*la as f64 - *lb as f64).abs() < tol,
                "{name} step {sa}: pjrt {la} vs native {lb} (tol {tol})"
            );
        }
    }
}
