//! Cross-layer integration tests: the Rust runtime executing the real
//! AOT-compiled HLO programs. Requires `make artifacts`.
//!
//! Tests are grouped into a few large functions so that each compiled
//! program is reused within a test thread (the PJRT runtime is
//! thread-local); small z0 programs keep compile times low.

use std::sync::Arc;

use spectron::config::{Registry, RunCfg};
use spectron::coordinator::{DataParallelSim, GradAccumulator};
use spectron::data::bpe::Bpe;
use spectron::data::corpus::{Corpus, CorpusCfg};
use spectron::data::dataset::{Dataset, Split};
use spectron::data::prefetch::Prefetcher;
use spectron::eval::{downstream, perplexity, Evaluator};
use spectron::linalg;
use spectron::runtime::state as slots;
use spectron::runtime::{ArtifactIndex, Runtime, StateHost};
use spectron::train::schedule::Schedule;
use spectron::train::{checkpoint, Trainer};
use spectron::util::rng::Pcg64;

const VARIANT: &str = "fact-z0-spectron";

fn artifacts() -> Option<ArtifactIndex> {
    let root = ArtifactIndex::default_root();
    if root.join("index.json").exists() {
        Some(ArtifactIndex::load(&root).unwrap())
    } else {
        eprintln!("skipping integration test: run `make artifacts` first");
        None
    }
}

fn tiny_dataset(vocab: usize) -> Arc<Dataset> {
    let corpus = Corpus::new(CorpusCfg::default());
    let sample = corpus.text_range(1, 150);
    let bpe = Bpe::train(&sample, vocab);
    Arc::new(Dataset::build_with(&corpus, &bpe, 800, 128))
}

fn run_cfg(steps: usize) -> RunCfg {
    RunCfg {
        total_steps: steps,
        base_lr: 0.01,
        weight_decay: 0.01,
        warmup_frac: 0.05,
        seed: 0,
        read_interval: 5,
    }
}

/// init -> step loop -> ring/telemetry/schedule/ckpt/resume, one compile.
#[test]
fn train_loop_end_to_end() {
    let Some(idx) = artifacts() else { return };
    let reg = Registry::load().unwrap();
    let rt = Runtime::shared().unwrap();
    let v = reg.variant(VARIANT).unwrap();
    let ds = tiny_dataset(v.model.vocab);
    let run = run_cfg(30);

    let mut trainer = Trainer::new(&rt, &idx, v, run.clone()).unwrap();
    assert_eq!(trainer.state().step(), 0);
    let mut batches = ds.batches(Split::Train, v.batch, 0);
    let res = trainer.train(&mut batches, 30).unwrap();

    // loss curve: starts near ln(vocab), strictly recorded per step
    assert_eq!(res.losses.len(), 30);
    assert!(res.losses.windows(2).all(|w| w[0].0 + 1 == w[1].0));
    let first = res.losses[0].1 as f64;
    assert!((first - (v.model.vocab as f64).ln()).abs() < 1.0, "{first}");
    assert!(res.final_loss < first - 0.5, "no learning: {first} -> {}", res.final_loss);
    assert!(!res.diverged);

    // header: schedule mirror agrees with the in-graph lr
    let sched = Schedule {
        total_steps: run.total_steps,
        base_lr: run.base_lr,
        warmup_frac: run.warmup_frac,
    };
    let host_lr = sched.lr_at(trainer.state().step() - 1);
    let graph_lr = trainer.state().lr() as f64;
    assert!(
        (host_lr - graph_lr).abs() / host_lr < 1e-4,
        "lr mirror drift: host {host_lr} vs graph {graph_lr}"
    );
    assert_eq!(
        trainer.state().tokens_seen(),
        (30 * v.batch * v.model.seq_len) as f64
    );

    // spectral telemetry: spectron's bound ||dW||_2 <= ~lr (Eq. 11)
    let tel = trainer.state().telemetry();
    assert!(tel[0] > 0.05, "w_spec {:?}", tel);
    assert!(tel[1] > 0.0 && (tel[1] as f64) <= 1.5 * graph_lr, "dw_spec {:?}", tel);
    assert!(tel[5] > 0.0 && tel[5] < trainer.state().lr(), "rho {:?}", tel);

    // telemetry cross-check: host power iteration on the state's factor
    // views reproduces sigma_a within power-iteration tolerance
    let manifest = idx.manifest(VARIANT).unwrap();
    let host = trainer.sync().unwrap().clone();
    let lyr = manifest.layers / 2;
    let a = host.tensor(&manifest, "attn_o_a").unwrap();
    let spec_a = manifest.tensor("attn_o_a").unwrap();
    let (m, r) = (spec_a.shape[1], spec_a.shape[2]);
    let a_mat = linalg::Mat::from_f32(m, r, &a[lyr * m * r..(lyr + 1) * m * r]);
    let mut rng = Pcg64::new(1);
    let sigma_host = linalg::spectral_norm(&a_mat, 60, &mut rng);
    let sigma_graph = tel[3] as f64;
    assert!(
        (sigma_host - sigma_graph).abs() / sigma_host < 0.05,
        "sigma_a: host {sigma_host} vs graph {sigma_graph}"
    );

    // checkpoint -> resume continues from the same step and keeps learning
    let ck = std::env::temp_dir().join(format!("spectron-int-{}.ckpt", std::process::id()));
    let state = trainer.state_vec().unwrap();
    checkpoint::save(&ck, VARIANT, &state).unwrap();
    let (ck_variant, loaded) = checkpoint::load(&ck).unwrap();
    assert_eq!(ck_variant, VARIANT);
    assert_eq!(loaded, state);
    let mut resumed = Trainer::from_state(&rt, &idx, v, run.clone(), loaded).unwrap();
    assert_eq!(resumed.state().step(), 30);
    let res2 = resumed.train(&mut batches, 10).unwrap();
    assert_eq!(resumed.state().step(), 40);
    assert!(res2.losses.first().unwrap().0 == 30);
    std::fs::remove_file(&ck).ok();
}

/// eval program: perplexity consistency + span restriction + downstream.
#[test]
fn eval_programs_end_to_end() {
    let Some(idx) = artifacts() else { return };
    let reg = Registry::load().unwrap();
    let rt = Runtime::shared().unwrap();
    let v = reg.variant(VARIANT).unwrap();
    let corpus = Corpus::new(CorpusCfg::default());
    let sample = corpus.text_range(1, 150);
    let bpe = Bpe::train(&sample, v.model.vocab);
    let ds = Arc::new(Dataset::build_with(&corpus, &bpe, 800, 128));

    let mut trainer = Trainer::new(&rt, &idx, v, run_cfg(25)).unwrap();
    let mut batches = ds.batches(Split::Train, v.batch, 0);
    trainer.train(&mut batches, 25).unwrap();
    let state = trainer.state_vec().unwrap();
    let manifest = idx.manifest(VARIANT).unwrap();
    let ev = Evaluator::new(&rt, &idx, &manifest).unwrap();
    let prefix = &state[..manifest.params_end];

    // perplexity far below uniform after training
    let ppl = perplexity::perplexity(&ev, prefix, &ds, 10).unwrap();
    assert!(ppl.ppl < v.model.vocab as f64 * 0.9, "ppl {}", ppl.ppl);
    assert!(ppl.tokens > 0.0);

    // an UNTRAINED model scores ~uniform — eval is actually using params
    let t2 = Trainer::new(&rt, &idx, v, run_cfg(25)).unwrap();
    let fresh = t2.state().data.clone();
    let ppl0 = perplexity::perplexity(&ev, &fresh[..manifest.params_end], &ds, 4).unwrap();
    assert!(
        (ppl0.ppl.ln() - (v.model.vocab as f64).ln()).abs() < 1.0,
        "fresh ppl {}",
        ppl0.ppl
    );
    assert!(ppl.ppl < ppl0.ppl * 0.8);

    // downstream suite runs and returns sane accuracies
    let suite = downstream::run_suite(&ev, prefix, &bpe, &corpus, 24, 7).unwrap();
    assert_eq!(suite.len(), 3);
    for t in &suite {
        assert!(t.accuracy >= 0.0 && t.accuracy <= 1.0);
        assert_eq!(t.n_items, 24);
    }
}

/// grad/apply path: equivalence with the fused step, accumulation, and
/// the simulated data-parallel runtime.
#[test]
fn coordinator_end_to_end() {
    let Some(idx) = artifacts() else { return };
    let reg = Registry::load().unwrap();
    let rt = Runtime::shared().unwrap();
    let v = reg.variant(VARIANT).unwrap();
    let ds = tiny_dataset(v.model.vocab);

    // (a) grad+apply == fused step on identical batches
    let run = run_cfg(10);
    let mut fused = Trainer::new(&rt, &idx, v, run.clone()).unwrap();
    let mut acc = GradAccumulator::new(&rt, &idx, v, run.clone()).unwrap();
    let mut b1 = ds.batches(Split::Train, v.batch, 0);
    let mut b2 = ds.batches(Split::Train, v.batch, 0);
    for _ in 0..3 {
        fused.train(&mut b1, 1).unwrap();
        acc.step(&mut b2, 1).unwrap();
    }
    let s_fused = fused.state_vec().unwrap();
    let s_acc = acc.state().unwrap().data;
    let manifest = idx.manifest(VARIANT).unwrap();
    let mut max_diff = 0f32;
    for i in manifest.hdr..manifest.state_len {
        max_diff = max_diff.max((s_fused[i] - s_acc[i]).abs());
    }
    // the two programs fuse differently, so f32 rounding diverges and the
    // Newton-Schulz polynomial amplifies it a little each step; ~1e-4/step
    // of drift is numerical, not semantic (python tests pin one step at 2e-5)
    assert!(max_diff < 3e-3, "fused vs grad/apply drift {max_diff}");

    // (b) accumulation over k microbatches trains stably
    let mut acc2 = GradAccumulator::new(&rt, &idx, v, run_cfg(10)).unwrap();
    let mut b3 = ds.batches(Split::Train, v.batch, 1);
    let mut losses = Vec::new();
    for _ in 0..6 {
        losses.push(acc2.step(&mut b3, 3).unwrap());
    }
    assert!(losses.last().unwrap() < losses.first().unwrap());

    // (c) DP sim: replicas share the state and the loss goes down;
    // all-reduce keeps the apply path identical to a global batch
    let mut dp = DataParallelSim::new(&rt, &idx, v, run_cfg(10), &ds, 3).unwrap();
    assert_eq!(dp.n_workers(), 3);
    let mut first = f64::NAN;
    let mut last = f64::NAN;
    for s in 0..6 {
        let stats = dp.step().unwrap();
        assert_eq!(stats.worker_losses.len(), 3);
        assert!(stats.grad_norm.is_finite());
        if s == 0 {
            first = stats.mean_loss;
        }
        last = stats.mean_loss;
    }
    assert!(last < first, "dp training did not progress: {first} -> {last}");
    let st = dp.state().unwrap();
    assert_eq!(st.step(), 6);
}

/// Pipelined hot path: training through the async prefetch ring is
/// bit-identical to training through the synchronous iterator (the
/// prefetcher only moves *when* a batch is packed, never what's in it or
/// how it is uploaded).
#[test]
fn prefetched_training_matches_sync() {
    let Some(idx) = artifacts() else { return };
    let reg = Registry::load().unwrap();
    let rt = Runtime::shared().unwrap();
    let v = reg.variant(VARIANT).unwrap();
    let ds = tiny_dataset(v.model.vocab);

    let mut t_sync = Trainer::new(&rt, &idx, v, run_cfg(12)).unwrap();
    let mut batches = ds.batches(Split::Train, v.batch, 3);
    t_sync.train(&mut batches, 12).unwrap();

    let mut t_pf = Trainer::new(&rt, &idx, v, run_cfg(12)).unwrap();
    let mut pf = Prefetcher::new(ds.clone(), Split::Train, v.batch, 3);
    t_pf.train(&mut pf, 12).unwrap();

    let a = t_sync.state_vec().unwrap();
    let b = t_pf.state_vec().unwrap();
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "state diverged at slot {i}");
    }
}

/// Threaded DP (persistent per-worker PJRT clients) is bit-identical to
/// the sequential reference: same reduced gradients, same state, for
/// every tested worker count.
#[test]
fn parallel_dp_matches_sequential() {
    let Some(idx) = artifacts() else { return };
    let reg = Registry::load().unwrap();
    let rt = Runtime::shared().unwrap();
    let v = reg.variant(VARIANT).unwrap();
    let ds = tiny_dataset(v.model.vocab);

    for n in [1usize, 2, 3, 8] {
        let mut seq = DataParallelSim::new(&rt, &idx, v, run_cfg(6), &ds, n).unwrap();
        let mut par = DataParallelSim::new_threaded(&rt, &idx, v, run_cfg(6), &ds, n).unwrap();
        assert!(!seq.is_threaded() && par.is_threaded());
        for s in 0..3 {
            let a = seq.step().unwrap();
            let b = par.step().unwrap();
            assert_eq!(a.worker_losses.len(), n);
            let la: Vec<u64> = a.worker_losses.iter().map(|x| x.to_bits()).collect();
            let lb: Vec<u64> = b.worker_losses.iter().map(|x| x.to_bits()).collect();
            assert_eq!(la, lb, "worker losses, n={n} step {s}");
            let ga: Vec<u32> = seq.last_reduced_grad().iter().map(|x| x.to_bits()).collect();
            let gb: Vec<u32> = par.last_reduced_grad().iter().map(|x| x.to_bits()).collect();
            assert_eq!(ga.len(), gb.len());
            assert!(ga == gb, "reduced grad bits differ, n={n} step {s}");
        }
        let sa = seq.state().unwrap().data;
        let sb = par.state().unwrap().data;
        for (i, (x, y)) in sa.iter().zip(&sb).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "state slot {i}, n={n}");
        }
        assert_eq!(seq.state().unwrap().step(), 3);
    }
}

/// Divergence is observed, not fatal: absurd lr on naive sgd.
#[test]
fn divergence_detection() {
    let Some(idx) = artifacts() else { return };
    let reg = Registry::load().unwrap();
    let rt = Runtime::shared().unwrap();
    let v = reg.variant(VARIANT).unwrap();
    let ds = tiny_dataset(v.model.vocab);
    let run = RunCfg {
        total_steps: 40,
        base_lr: 500.0, // absurd
        weight_decay: 0.0,
        warmup_frac: 0.0,
        seed: 0,
        read_interval: 2,
    };
    let mut trainer = Trainer::new(&rt, &idx, v, run).unwrap();
    let mut batches = ds.batches(Split::Train, v.batch, 0);
    let res = trainer.train(&mut batches, 40).unwrap();
    assert!(res.diverged, "expected divergence at lr=500");
    assert!(res.steps_done < 40, "should stop early");
}

/// Manifest header constants: python and rust layouts agree everywhere.
#[test]
fn header_layout_cross_check() {
    let Some(idx) = artifacts() else { return };
    for name in &idx.variants {
        let m = idx.manifest(name).unwrap();
        assert_eq!(m.hdr, slots::HDR, "{name}");
        assert_eq!(m.ring, slots::RING, "{name}");
        assert_eq!(m.ring_base, slots::RING_BASE, "{name}");
        // StateHost::new re-validates
        let fake = vec![0f32; m.state_len];
        StateHost::new(fake, &m).unwrap();
    }
}
