//! Zero net per-step heap growth in the native training loop
//! (docs/adr/008-f32-compute-path.md, DESIGN.md §Native tensor core).
//!
//! A counting global allocator tracks *live* bytes. After a short
//! warmup (which populates the arena, the backward scratch, the
//! optimizer's decoded mirrors, and the NS/telemetry buffers), repeated
//! identical steps must return the allocator to exactly the same live
//! footprint: everything parameter-sized is recycled, and what little
//! still allocates per step (the transient model decode, the output
//! vector) frees exactly what it takes.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicIsize, Ordering};

use spectron::config::Registry;
use spectron::linalg::simd;
use spectron::runtime::{NativeBackend, Precision};
use spectron::util::rng::Pcg64;

/// System allocator wrapped with a live-byte counter. `Relaxed` is
/// enough: the test reads the counter only while the loop is quiescent.
struct Counting;

static LIVE: AtomicIsize = AtomicIsize::new(0);

unsafe impl GlobalAlloc for Counting {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            LIVE.fetch_add(layout.size() as isize, Ordering::Relaxed);
        }
        p
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        LIVE.fetch_sub(layout.size() as isize, Ordering::Relaxed);
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            LIVE.fetch_add(new_size as isize - layout.size() as isize, Ordering::Relaxed);
        }
        p
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc_zeroed(layout);
        if !p.is_null() {
            LIVE.fetch_add(layout.size() as isize, Ordering::Relaxed);
        }
        p
    }
}

#[global_allocator]
static ALLOC: Counting = Counting;

fn steady_loop(precision: Precision) {
    let reg = Registry::load().unwrap();
    let mut cfg = reg.variant("fact-z0-spectron").unwrap().clone();
    cfg.model.vocab = 48;
    cfg.model.seq_len = 10;
    cfg.batch = 2;
    // threads = 1 keeps the whole loop on this thread (no pool workers
    // with their own stacks/queues muddying the counter)
    let be = NativeBackend::with_opts(&cfg, 1, precision).unwrap();
    let knobs = [100.0, 0.02, 0.01, 0.1, 0.0, 0.0, 0.0, 0.0];
    let mut state = be.init_state(1, &knobs);
    let (b, w) = (cfg.batch, cfg.model.seq_len + 1);
    let mut rng = Pcg64::new(5);
    let toks: Vec<i32> =
        (0..b * w).map(|_| rng.below(cfg.model.vocab as u64) as i32).collect();

    // warmup: grows the arena, backward scratch, decoded optimizer
    // mirrors, grad map, NS/telemetry scratch to their steady shapes
    for _ in 0..3 {
        state = be.step_state(&state, &toks).unwrap();
    }
    let baseline = LIVE.load(Ordering::Relaxed);
    for k in 0..10 {
        state = be.step_state(&state, &toks).unwrap();
        let now = LIVE.load(Ordering::Relaxed);
        assert_eq!(
            now - baseline,
            0,
            "step {k} leaked {} net bytes ({precision:?})",
            now - baseline
        );
    }
}

/// One test, both precisions and both SIMD tiers in sequence: the
/// live-byte counter is process-global, so a concurrently running
/// sibling test (or the harness thread printing its result) would race
/// the baseline. A single test keeps the whole binary quiescent during
/// measurement.
///
/// The SIMD dispatch table is resolved (env read + cpuid) up front,
/// before any warmup: resolution allocates a transient `String` for
/// `REPRO_SIMD`, and pulling it forward proves the steady loop itself
/// stays at zero net growth under both the portable and the detected
/// vector table (docs/adr/010-simd-microkernels.md).
#[test]
fn training_loop_has_zero_net_per_step_heap_growth() {
    let _ = simd::active(); // resolve REPRO_SIMD + cpuid outside the loop
    let vec_lvl = simd::detected();
    simd::force(Some(simd::Level::Scalar));
    steady_loop(Precision::F64);
    steady_loop(Precision::F32);
    if vec_lvl != simd::Level::Scalar {
        simd::force(Some(vec_lvl));
        steady_loop(Precision::F64);
        steady_loop(Precision::F32);
    }
    simd::force(None);
}
