#!/usr/bin/env python3
"""Regenerate rust/tests/fixtures/state_layout.json from the build side.

The fixture pins the flat-state layout (tensor names, shapes, offsets,
section boundaries) that ``python/compile/state.py`` produces, so the Rust
mirror in ``rust/src/runtime/layout.rs`` can be golden-tested against it
without JAX or artifacts present. Run from the repo root:

    python3 tools/gen_layout_fixture.py

and commit the result whenever the layout intentionally changes.
"""

import json
import os
import sys

REPO = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(REPO, "python"))

from compile.config import load_variants  # noqa: E402
from compile.state import StateLayout  # noqa: E402

# One variant per optimizer branch plus both non-"all" factorize modes —
# every code path of StateLayout._build_opt is covered.
VARIANTS = [
    "fact-z0-spectron",
    "fact-s-adamw",
    "fact-s-sgd",
    "fact-s-muon",
    "fact-s-renorm",
    "fact-s-selfguided",
    "ffn-s-spectron",
    "dense-s-muon",
]


def main() -> None:
    variants = load_variants()
    out = {}
    for name in VARIANTS:
        layout = StateLayout(variants[name])
        m = layout.manifest()
        out[name] = {
            "state_len": m["state_len"],
            "hdr": m["hdr"],
            "ring": m["ring"],
            "ring_base": m["ring_base"],
            "params_end": m["params_end"],
            "n_params": m["n_params"],
            "eval_key": m["eval_key"],
            "tensors": m["tensors"],
        }
    path = os.path.join(REPO, "rust", "tests", "fixtures", "state_layout.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {path} ({len(out)} variants)")


if __name__ == "__main__":
    main()
