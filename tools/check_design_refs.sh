#!/usr/bin/env bash
# Verify every `DESIGN.md §X` citation in the source tree resolves to a
# real `## X` heading in DESIGN.md (run by `make docs`). Section names
# start with a capitalized word; following lowercase words belong to the
# name ("Experiment index"); any punctuation ends it.
set -euo pipefail
cd "$(dirname "$0")/.."

pattern='DESIGN\.md §[A-Z][A-Za-z0-9_-]*( [a-z][A-Za-z0-9_-]*)*'
bad=0
count=0
while IFS=: read -r file line match; do
    [ -n "$match" ] || continue
    section=${match#DESIGN.md §}
    count=$((count + 1))
    if ! grep -qxF "## $section" DESIGN.md; then
        echo "BROKEN: $file:$line cites 'DESIGN.md §$section' but DESIGN.md has no '## $section' heading" >&2
        bad=1
    fi
done < <(grep -rnoE "$pattern" rust python examples 2>/dev/null || true)

if [ "$count" -eq 0 ]; then
    echo "check_design_refs: found no citations — pattern drift?" >&2
    exit 1
fi
if [ "$bad" -ne 0 ]; then
    exit 1
fi
echo "check_design_refs: $count citations OK"
