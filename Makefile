# Build/verify entry points. `make artifacts` is the only step that
# needs Python; everything after runs from the self-contained `repro`
# binary (DESIGN.md).

.PHONY: artifacts build test ci docs bench bench-native serve-bench serve-test route-test route-bench obs-test sweep-smoke clean

# Lower every variant's programs to HLO text + manifests.
artifacts:
	cd python && python3 -m compile.aot --out ../artifacts

build:
	cargo build --release

# Tier-1 verify (ROADMAP.md).
test: build
	cargo test -q

# The full gate (run by .github/workflows/ci.yml): build + the whole
# Rust suite (native backend ungated; PJRT parameterizations activate
# when artifacts/ exists), the build-side python tests when jax is
# importable, and the doc gate. Meaningful without any artifacts: the
# native backend keeps every integration test live (DESIGN.md §Backends).
ci: build
	cargo test -q
	@if python3 -c "import jax" >/dev/null 2>&1; then \
		echo "ci: running build-side python tests"; \
		cd python && python3 -m pytest -q tests; \
	else \
		echo "ci: python+jax unavailable — skipping build-side tests"; \
	fi
	$(MAKE) docs

# Doc gate: rustdoc clean of warnings (broken intra-doc links included)
# and every in-source `DESIGN.md §X` citation resolving to a heading.
docs:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
	bash tools/check_design_refs.sh

# Benchmarks; the hot-path suites also emit machine-readable JSON
# (BENCH_JSON=path, see rust/src/util/bench.rs) so the committed latency
# trajectory is diffable. NOTE: suites are listed explicitly so the two
# JSON emitters get distinct BENCH_JSON paths — a new [[bench]] in
# Cargo.toml must be added here too or `make bench` silently skips it.
bench:
	BENCH_JSON=BENCH_step_latency.json cargo bench --bench step_latency
	BENCH_JSON=BENCH_data_pipeline.json cargo bench --bench data_pipeline
	BENCH_JSON=BENCH_native_math.json cargo bench --bench native_math
	cargo bench --bench runtime_io
	cargo bench --bench scaling_fits
	cargo bench --bench serve_latency

# Tensor-core microbenches alone (DESIGN.md §Native tensor core): matmul /
# Newton-Schulz / power-iter across threads and alloc-reuse, plus the
# dense-baseline vs factored-apply rows in both compute precisions
# (docs/adr/008). No artifacts needed; CI smokes it with BENCH_FAST=1 and
# BENCH_ASSERT_FACTORED=1 (factored must beat dense at the logits shape).
bench-native:
	BENCH_JSON=BENCH_native_math.json cargo bench --bench native_math

# Open-loop serving latency (examples/serve_bench.rs): generate traffic
# at fixed arrival rates against the native engine, KV-cache continuous
# batching vs the lockstep baseline; p50/p95/p99 per (rate, mode) land in
# BENCH_serve_latency.json (docs/adr/006).
serve-bench:
	BENCH_JSON=BENCH_serve_latency.json cargo run --release --example serve_bench

# The serving integration suite under both thread budgets: the KV-cache
# decode path promises bit-identity with the full forward, so a threaded
# tensor core must reproduce the exact serial transcripts (docs/adr/006).
serve-test:
	REPRO_THREADS=1 cargo test -q --test serve_integration
	REPRO_THREADS=4 cargo test -q --test serve_integration

# The router suite under both thread budgets (DESIGN.md §Routing,
# docs/adr/007): byte-identical pass-through, retry/backoff on sheds,
# drain/resume cycles, chaos-proxy outages, and the SIGKILL failover
# test against supervised child replicas.
route-test:
	REPRO_THREADS=1 cargo test -q --test route_integration
	REPRO_THREADS=4 cargo test -q --test route_integration

# The observability suite (DESIGN.md §Observability, docs/adr/009):
# exact counters under contention, consistent snapshots, bit-identical
# traced training at both thread budgets and precisions, and
# schema-valid Chrome trace export.
obs-test:
	cargo test -q --test obs

# Open-loop routed score latency (examples/serve_bench.rs under
# ROUTE_BENCH=1): 1 replica, 2 replicas, and 2 replicas with a mid-run
# chaos outage; rows land in BENCH_route_latency.json. The outage row's
# acceptance signal: zero failed requests, failover cost in the tail.
route-bench:
	ROUTE_BENCH=1 BENCH_JSON=BENCH_route_latency.json cargo run --release --example serve_bench

# Sweep resumability smoke (DESIGN.md §Monitoring and sweeps): run the
# built-in grid with a simulated kill after the first run, rerun twice,
# and assert the finished runs are skipped — i.e. crash + rerun never
# retrains completed work. Native backend: no artifacts needed.
sweep-smoke: build
	rm -rf results/sweeps/smoke
	./target/release/repro sweep --smoke --max-runs 1 --backend native
	./target/release/repro sweep --smoke --backend native | tee sweep-smoke-2.log
	grep -q "skipped: 1" sweep-smoke-2.log
	./target/release/repro sweep --smoke --backend native | tee sweep-smoke-3.log
	grep -q "executed: 0  skipped: 2" sweep-smoke-3.log
	./target/release/repro sweep-report --name smoke
	rm -f sweep-smoke-2.log sweep-smoke-3.log

clean:
	rm -rf target artifacts results
