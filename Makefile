# Build/verify entry points. `make artifacts` is the only step that
# needs Python; everything after runs from the self-contained `repro`
# binary (DESIGN.md).

.PHONY: artifacts build test docs bench serve-bench clean

# Lower every variant's programs to HLO text + manifests.
artifacts:
	cd python && python3 -m compile.aot --out ../artifacts

build:
	cargo build --release

# Tier-1 verify (ROADMAP.md).
test: build
	cargo test -q

# Doc gate: rustdoc clean of warnings (broken intra-doc links included)
# and every in-source `DESIGN.md §X` citation resolving to a heading.
docs:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
	bash tools/check_design_refs.sh

bench:
	cargo bench

serve-bench:
	cargo run --release --example serve_bench

clean:
	rm -rf target artifacts results
