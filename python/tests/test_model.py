"""L2 model correctness: shapes, causality, factorization modes, grads."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import forward, loss_fn, rms_norm, rope_tables, apply_rope, token_nll
from compile.programs import _init_tensors
from compile.state import StateLayout, is_factorized

from .conftest import variant


def _setup(optimizer="spectron", factorize="all", **kw):
    cfg = variant(optimizer=optimizer, factorize=factorize, **kw)
    layout = StateLayout(cfg)
    tensors = _init_tensors(layout, jax.random.PRNGKey(0))
    return cfg, layout, tensors


def _tokens(cfg, key=0):
    k = jax.random.PRNGKey(key)
    return jax.random.randint(k, (cfg.batch, cfg.model.seq_len), 0, cfg.model.vocab)


@pytest.mark.parametrize("factorize", ["all", "ffn", "none"])
def test_forward_shapes(factorize):
    cfg, layout, tensors = _setup(factorize=factorize)
    logits = forward(tensors, _tokens(cfg), cfg)
    assert logits.shape == (cfg.batch, cfg.model.seq_len, cfg.model.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_causality():
    """Changing a future token must not change past logits."""
    cfg, layout, tensors = _setup()
    toks = _tokens(cfg)
    logits1 = forward(tensors, toks, cfg)
    toks2 = toks.at[:, -1].set((toks[:, -1] + 1) % cfg.model.vocab)
    logits2 = forward(tensors, toks2, cfg)
    np.testing.assert_allclose(
        np.asarray(logits1[:, :-1]), np.asarray(logits2[:, :-1]), atol=1e-5
    )
    assert not np.allclose(np.asarray(logits1[:, -1]), np.asarray(logits2[:, -1]))


def test_initial_loss_near_uniform():
    cfg, layout, tensors = _setup()
    k = jax.random.PRNGKey(5)
    toks = jax.random.randint(k, (cfg.batch, cfg.model.seq_len + 1), 0, cfg.model.vocab)
    loss = float(loss_fn(tensors, toks, cfg))
    assert abs(loss - np.log(cfg.model.vocab)) < 0.75, loss


def test_grads_flow_to_all_params():
    cfg, layout, tensors = _setup()
    k = jax.random.PRNGKey(5)
    toks = jax.random.randint(k, (cfg.batch, cfg.model.seq_len + 1), 0, cfg.model.vocab)
    pnames = layout.param_names()
    grads = jax.grad(
        lambda tr: loss_fn({**tensors, **tr}, toks, cfg)
    )({n: tensors[n] for n in pnames})
    for n in pnames:
        g = np.asarray(grads[n])
        assert np.isfinite(g).all(), n
        if n != "embed":  # embed rows for unseen tokens legitimately zero
            assert np.abs(g).max() > 0, f"zero grad for {n}"


def test_factorized_params_fewer_than_dense():
    _, lf, _ = _setup(factorize="all")
    _, ld, _ = _setup(factorize="none")
    _, lffn, _ = _setup(factorize="ffn")
    assert lf.n_params < lffn.n_params < ld.n_params


def test_selfguided_alpha_mixing():
    """alpha=1 must reproduce the dense auxiliary path exactly."""
    cfg, layout, tensors = _setup(optimizer="selfguided")
    toks = _tokens(cfg)
    # alpha=0: pure factorized == forward without alpha
    l0 = forward(tensors, toks, cfg, alpha=jnp.float32(0.0))
    lfact = forward({k: v for k, v in tensors.items() if not k.startswith("sg.")},
                    toks, cfg)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(lfact), atol=1e-5)
    # at init W0 = A0 B0^T so alpha=1 and alpha=0 agree too (paper Eq. 18)
    l1 = forward(tensors, toks, cfg, alpha=jnp.float32(1.0))
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l0), atol=1e-3)


def test_rms_norm_unit_scale():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64)) * 7.0
    y = rms_norm(x, jnp.ones(64))
    rms = np.sqrt(np.mean(np.asarray(y) ** 2, axis=-1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)


def test_rope_preserves_norm_and_relativity():
    cos, sin = rope_tables(16, 8)
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 16, 2, 8))
    y = apply_rope(x, cos, sin)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )
    # relative property: <R_i q, R_j k> depends only on i - j
    q = jax.random.normal(jax.random.PRNGKey(1), (8,))
    k = jax.random.normal(jax.random.PRNGKey(2), (8,))
    qk = jnp.stack([q, k])[None, None]  # (1,1,2,8) -> rotate both
    def dot_at(i, j):
        qi = apply_rope(jnp.broadcast_to(q, (1, 16, 1, 8)), cos, sin)[0, i, 0]
        kj = apply_rope(jnp.broadcast_to(k, (1, 16, 1, 8)), cos, sin)[0, j, 0]
        return float(qi @ kj)
    assert abs(dot_at(5, 3) - dot_at(9, 7)) < 1e-4
    assert abs(dot_at(5, 3) - dot_at(5, 2)) > 1e-6


def test_token_nll_matches_manual():
    logits = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 16))
    targets = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0, 16)
    nll = token_nll(logits, targets)
    lp = jax.nn.log_softmax(logits, -1)
    want = -np.take_along_axis(np.asarray(lp), np.asarray(targets)[..., None], -1)[..., 0]
    np.testing.assert_allclose(np.asarray(nll), want, atol=1e-5)
