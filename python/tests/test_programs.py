"""Program-level integration: init/step/eval/grad/apply compose correctly.

These run the same jitted functions that aot.py lowers — anything green
here is exactly what the Rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import state as st
from compile.programs import (
    make_apply,
    make_eval,
    make_grad,
    make_init,
    make_logits,
    make_step,
)
from compile.state import HDR, StateLayout

from .conftest import variant

KNOBS = jnp.asarray([40.0, 0.01, 0.01, 0.05, 0, 0, 0, 0], jnp.float32)


def _boot(optimizer="spectron", telemetry=True, **kw):
    cfg = variant(optimizer=optimizer, telemetry=telemetry, **kw)
    layout = StateLayout(cfg)
    state = jax.jit(make_init(layout))(jnp.int32(0), KNOBS)
    toks = jax.random.randint(
        jax.random.PRNGKey(3), (cfg.batch, cfg.model.seq_len + 1), 0, cfg.model.vocab
    )
    return cfg, layout, state, toks


def test_init_header_knobs_and_zero_step():
    _, layout, state, _ = _boot()
    h = np.asarray(state[:HDR])
    assert h[st.STEP] == 0
    assert h[st.TOTAL_STEPS] == 40
    assert h[st.BASE_LR] == pytest.approx(0.01)
    assert h[st.WEIGHT_DECAY] == pytest.approx(0.01)
    assert (h[st.RING_BASE:]).sum() == 0


def test_init_deterministic_and_seed_sensitive():
    _, layout, s0, _ = _boot()
    s0b = jax.jit(make_init(layout))(jnp.int32(0), KNOBS)
    s1 = jax.jit(make_init(layout))(jnp.int32(1), KNOBS)
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s0b))
    assert not np.allclose(np.asarray(s0), np.asarray(s1))


@pytest.mark.parametrize("optimizer", ["adamw", "spectron", "selfguided", "muon"])
def test_loss_decreases_on_repeated_batch(optimizer):
    cfg, layout, state, toks = _boot(optimizer)
    step = jax.jit(make_step(layout, use_pallas=False))
    losses = []
    for _ in range(8):
        state = step(state, toks)
        losses.append(float(state[st.LOSS]))
    assert losses[-1] < losses[0] - 0.3, losses
    assert all(np.isfinite(losses))


def test_step_advances_counter_and_ring():
    cfg, layout, state, toks = _boot()
    step = jax.jit(make_step(layout, use_pallas=False))
    for i in range(3):
        state = step(state, toks)
        h = np.asarray(state[:HDR])
        assert h[st.STEP] == i + 1
        assert h[st.RING_BASE + i] == pytest.approx(h[st.LOSS]) or i < 2
    h = np.asarray(state[:HDR])
    assert (h[st.RING_BASE : st.RING_BASE + 3] > 0).all()
    assert h[st.TOKENS_SEEN] == 3 * cfg.batch * cfg.model.seq_len


def test_telemetry_slots_populated():
    cfg, layout, state, toks = _boot("spectron", telemetry=True)
    step = jax.jit(make_step(layout, use_pallas=False))
    state = step(state, toks)
    h = np.asarray(state[:HDR])
    assert h[st.W_SPEC] > 0.1
    assert h[st.DW_SPEC] > 0
    assert h[st.DY_RMS] > 0
    assert h[st.SIGMA_A] > 0 and h[st.SIGMA_B] > 0
    # paper Eq. 11: the tracked composite update respects the lr bound
    assert h[st.DW_SPEC] <= 1.4 * h[st.LR]


def test_grad_apply_equals_fused_step():
    cfg, layout, state, toks = _boot("spectron")
    step = jax.jit(make_step(layout, use_pallas=False))
    grad = jax.jit(make_grad(layout))
    apply = jax.jit(make_apply(layout, use_pallas=False))
    fused = step(state, toks)
    gv = grad(state, toks)
    split = apply(state, gv)
    np.testing.assert_allclose(
        np.asarray(fused[HDR:]), np.asarray(split[HDR:]), atol=2e-5
    )
    assert float(gv[0]) == pytest.approx(float(fused[st.LOSS]), abs=1e-5)


def test_grad_linearity_supports_allreduce():
    """mean of per-shard grads == grad of the full batch (what the
    coordinator's all-reduce assumes for equal-size shards)."""
    cfg, layout, state, toks = _boot("spectron")
    grad = jax.jit(make_grad(layout))
    g_full = np.asarray(grad(state, toks)[1:])
    half = cfg.batch // 2
    g1 = np.asarray(grad(state, toks[:half].repeat(2, 0))[1:])
    g2 = np.asarray(grad(state, toks[half:].repeat(2, 0))[1:])
    np.testing.assert_allclose(0.5 * (g1 + g2), g_full, atol=1e-4)


def test_eval_matches_train_loss():
    cfg, layout, state, toks = _boot("spectron")
    ev = jax.jit(make_eval(layout))
    spans = jnp.broadcast_to(
        jnp.asarray([0, cfg.model.seq_len + 1], jnp.int32), (cfg.batch, 2)
    )
    out = ev(state[: layout.params_end], toks, spans)
    total_nll, total_cnt = float(out[0]), float(out[1])
    assert total_cnt == cfg.batch * cfg.model.seq_len
    from compile.model import loss_fn
    from compile.programs import _unpack_params_only

    _, tensors = _unpack_params_only(layout, state[: layout.params_end])
    want = float(loss_fn(tensors, toks, cfg))
    assert total_nll / total_cnt == pytest.approx(want, abs=1e-4)


def test_eval_span_restriction():
    cfg, layout, state, toks = _boot("spectron")
    ev = jax.jit(make_eval(layout))
    T = cfg.model.seq_len + 1
    spans = jnp.stack(
        [jnp.full((cfg.batch,), 4, jnp.int32), jnp.full((cfg.batch,), 10, jnp.int32)],
        axis=1,
    )
    out = ev(state[: layout.params_end], toks, spans)
    cnts = np.asarray(out[2 + cfg.batch :])
    np.testing.assert_array_equal(cnts, np.full(cfg.batch, 5.0))  # [4, 9) scored


def test_logits_matches_forward_rows():
    """The serve decode program returns forward()'s logit row at pos[i],
    flattened — the contract the Rust generate path decodes against."""
    cfg, layout, state, _ = _boot("spectron")
    lg = jax.jit(make_logits(layout))
    T, V = cfg.model.seq_len, cfg.model.vocab
    toks = jax.random.randint(jax.random.PRNGKey(9), (cfg.batch, T), 0, V)
    pos = jnp.asarray([3, T - 1], jnp.int32)
    out = np.asarray(lg(state[: layout.params_end], toks, pos))
    assert out.shape == (cfg.batch * V,)

    from compile.model import forward
    from compile.programs import _unpack_params_only

    _, tensors = _unpack_params_only(layout, state[: layout.params_end])
    full = np.asarray(forward(tensors, toks, cfg))
    for i in range(cfg.batch):
        np.testing.assert_allclose(
            out[i * V : (i + 1) * V], full[i, int(pos[i])], atol=1e-5
        )


def test_logits_causal_padding_inert():
    """Tokens after pos[i] (the PAD tail of a decode window) must not
    change the logits at pos[i] — the batcher left-aligns prompts and
    relies on causality for the padding."""
    cfg, layout, state, _ = _boot("spectron")
    lg = jax.jit(make_logits(layout))
    T, V = cfg.model.seq_len, cfg.model.vocab
    toks = jax.random.randint(jax.random.PRNGKey(10), (cfg.batch, T), 2, V)
    pos = jnp.full((cfg.batch,), 5, jnp.int32)
    base = np.asarray(lg(state[: layout.params_end], toks, pos))
    scrambled = toks.at[:, 6:].set(0)
    alt = np.asarray(lg(state[: layout.params_end], scrambled, pos))
    np.testing.assert_allclose(base, alt, atol=1e-5)


def test_divergence_is_observable_not_fatal():
    """With an absurd lr, naive sgd blows up; the step must still produce
    finite-or-inf header values the Rust trainer can detect (no crash)."""
    cfg = variant(optimizer="sgd")
    layout = StateLayout(cfg)
    # sgd's normalized update is insensitive to lr up to ~1e6 on this jax
    # build; 1e8 reliably overflows to nan, which is the observable case
    knobs = jnp.asarray([40.0, 1e8, 0.0, 0.0, 0, 0, 0, 0], jnp.float32)
    state = jax.jit(make_init(layout))(jnp.int32(0), knobs)
    toks = jax.random.randint(
        jax.random.PRNGKey(3), (cfg.batch, cfg.model.seq_len + 1), 0, cfg.model.vocab
    )
    step = jax.jit(make_step(layout, use_pallas=False))
    for _ in range(4):
        state = step(state, toks)
    loss = float(state[st.LOSS])
    assert not (loss < 20.0), loss  # diverged (large or nan) — detectable
