"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracles.

Hypothesis sweeps shapes and dtypes — the CORE correctness signal for the
kernels that end up inside every lowered train step.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not baked into this image")
from hypothesis import given, settings, strategies as hst

from compile.kernels import (
    lowrank_matmul,
    lowrank_matmul_ref,
    newton_schulz,
    newton_schulz_ref,
    power_iter,
    power_iter_ref,
)

DIMS = hst.sampled_from([8, 16, 24, 32, 64, 96, 128])
RANKS = hst.sampled_from([8, 16, 32])
DTYPES = hst.sampled_from([jnp.float32, jnp.bfloat16])


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape).astype(dtype)


# ---------------------------------------------------------------------------
# Newton-Schulz
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(m=DIMS, r=RANKS, seed=hst.integers(0, 2**30), dtype=DTYPES)
def test_ns_pallas_matches_ref(m, r, seed, dtype):
    if m < r:
        m, r = r, m
    g = _rand(jax.random.PRNGKey(seed), (m, r), dtype)
    got = newton_schulz(g, use_pallas=True)
    want = newton_schulz(g, use_pallas=False)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


@settings(max_examples=15, deadline=None)
@given(lyr=hst.integers(1, 5), seed=hst.integers(0, 2**30))
def test_ns_stacked_matches_per_slice(lyr, seed):
    g = _rand(jax.random.PRNGKey(seed), (lyr, 48, 16))
    stacked = newton_schulz(g)
    for i in range(lyr):
        np.testing.assert_allclose(
            np.asarray(stacked[i]), np.asarray(newton_schulz(g[i])), atol=1e-5
        )


@settings(max_examples=15, deadline=None)
@given(m=DIMS, r=RANKS, seed=hst.integers(0, 2**30))
def test_ns_orthogonalizes(m, r, seed):
    """All singular values of NS(G) approach 1 (Jordan et al. coefficients
    oscillate in ~[0.7, 1.2] — check that band, not exact unity)."""
    if m < r:
        m, r = r, m
    g = _rand(jax.random.PRNGKey(seed), (m, r))
    o = newton_schulz(g)
    s = jnp.linalg.svd(o.astype(jnp.float32), compute_uv=False)
    assert float(s.max()) < 1.35, s
    # near-square Gaussians have near-zero smallest singular values that 5
    # NS iterations cannot lift to ~1 (quintic convergence is slow near 0);
    # require the tight band only for well-separated aspect ratios, which
    # is what every factor matrix in the model satisfies (m >= 2r).
    if m >= 2 * r:
        assert float(s.min()) > 0.5, s
    else:
        assert float(s.min()) >= 0.0


def test_ns_wide_matrix_falls_back():
    g = _rand(jax.random.PRNGKey(3), (16, 64))
    np.testing.assert_allclose(
        np.asarray(newton_schulz(g)),
        np.asarray(newton_schulz_ref(g)),
        atol=1e-5,
    )


def test_ns_zero_input_is_finite():
    o = newton_schulz(jnp.zeros((32, 8)))
    assert np.isfinite(np.asarray(o)).all()


# ---------------------------------------------------------------------------
# Power iteration
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(m=DIMS, r=RANKS, seed=hst.integers(0, 2**30), iters=hst.integers(1, 4))
def test_power_iter_matches_ref(m, r, seed, iters):
    if m < r:
        m, r = r, m
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    w = _rand(k1, (m, r))
    u = _rand(k2, (m,))
    s1, u1 = power_iter(w, u, iters=iters, use_pallas=True)
    s2, u2 = power_iter(w, u, iters=iters, use_pallas=False)
    np.testing.assert_allclose(float(s1), float(s2), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(u1), np.asarray(u2), atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(m=DIMS, r=RANKS, seed=hst.integers(0, 2**30))
def test_power_iter_converges_to_svd(m, r, seed):
    if m < r:
        m, r = r, m
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    w = _rand(k1, (m, r))
    u = _rand(k2, (m,))
    sigma, _ = power_iter(w, u, iters=60)
    true = float(jnp.linalg.svd(w, compute_uv=False)[0])
    # convergence rate depends on the spectral gap; random Gaussians can be
    # nearly degenerate, so allow a small relative error (the estimate is
    # used inside a +1-regularized denominator).
    assert abs(float(sigma) - true) / true < 0.02
    assert float(sigma) <= true * (1.0 + 1e-4)  # Rayleigh quotient never overshoots


def test_power_iter_persisted_u_improves():
    """One iteration per call with a persisted u converges across calls —
    the property Spectron's opt-state vectors rely on."""
    k = jax.random.PRNGKey(0)
    w = _rand(k, (96, 24))
    true = float(jnp.linalg.svd(w, compute_uv=False)[0])
    u = _rand(jax.random.PRNGKey(1), (96,))
    errs = []
    for _ in range(24):
        s, u = power_iter(w, u, iters=1)
        errs.append(abs(float(s) - true) / true)
    # random Gaussian factors have a small spectral gap, so convergence is
    # slow — require clear improvement and a few-percent estimate, which is
    # all the renormalization denominator (sigma_A + sigma_B + 1) needs.
    assert errs[-1] < 0.05
    assert errs[-1] <= errs[0] * 0.5 + 1e-9


def test_power_iter_rank1_exact():
    a = jnp.arange(1, 9, dtype=jnp.float32)
    w = jnp.outer(a, jnp.ones(4)) / 2.0
    s, _ = power_iter(w, jnp.ones(8), iters=5)
    true = float(jnp.linalg.norm(a)) * float(jnp.linalg.norm(jnp.ones(4))) / 2.0
    np.testing.assert_allclose(float(s), true, rtol=1e-5)


# ---------------------------------------------------------------------------
# Fused low-rank matmul
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(
    t=hst.sampled_from([16, 32, 64, 128]),
    n=DIMS,
    m=DIMS,
    r=RANKS,
    seed=hst.integers(0, 2**30),
)
def test_lowrank_matmul_matches_ref(t, n, m, r, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = _rand(k1, (t, n))
    a = _rand(k2, (m, r))
    b = _rand(k3, (n, r))
    got = lowrank_matmul(x, a, b, block_t=min(16, t))
    want = lowrank_matmul_ref(x, a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_lowrank_matmul_equals_dense_product():
    k = jax.random.PRNGKey(9)
    x = _rand(k, (32, 24))
    a = _rand(jax.random.PRNGKey(1), (40, 8))
    b = _rand(jax.random.PRNGKey(2), (24, 8))
    w = a @ b.T
    np.testing.assert_allclose(
        np.asarray(lowrank_matmul(x, a, b, block_t=32)),
        np.asarray(x @ w.T),
        atol=1e-4,
    )


def test_lowrank_matmul_rejects_ragged_blocks():
    with pytest.raises(AssertionError):
        lowrank_matmul(jnp.zeros((30, 8)), jnp.zeros((8, 4)), jnp.zeros((8, 4)),
                       block_t=16)
