"""AOT lowering: HLO text generation and manifest contracts.

Uses a throwaway tiny variant so the test doesn't depend on (or clobber)
the real artifacts/ tree.
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile.aot import lower_eval, lower_variant, to_hlo_text
from compile.config import load_models, load_variants
from compile.state import HDR, StateLayout

from .conftest import variant


def test_to_hlo_text_is_parseable_hlo(rng):
    lowered = jax.jit(lambda x: x * 2.0 + 1.0).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32)
    )
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[4]" in text
    # single-output convention: root is an array, not a tuple
    root_lines = [l for l in text.splitlines() if "ROOT" in l]
    assert root_lines, text
    assert all("tuple(" not in l for l in root_lines), root_lines


def test_lower_variant_writes_programs_and_manifest(tmp_path):
    cfg = variant(optimizer="spectron", programs=("init", "step", "eval"))
    entry = lower_variant(cfg, str(tmp_path))
    vdir = tmp_path / cfg.name
    assert (vdir / "init.hlo.txt").stat().st_size > 1000
    assert (vdir / "step.hlo.txt").stat().st_size > 1000
    man = json.loads((vdir / "manifest.json").read_text())
    layout = StateLayout(cfg)
    assert man["state_len"] == layout.total
    assert man["hdr"] == HDR
    assert man["programs"].keys() == {"init", "step"}
    assert entry["programs"]["step"].endswith("step.hlo.txt")
    # tensor table is gapless and covers the state
    cursor = HDR
    for t in man["tensors"]:
        assert t["offset"] == cursor
        size = 1
        for d in t["shape"]:
            size *= d
        cursor += size
    assert cursor == man["state_len"]


def test_lower_eval_shares_across_optimizers(tmp_path):
    a = variant(optimizer="spectron")
    b = variant(optimizer="adamw")
    assert a.eval_key == b.eval_key
    meta = lower_eval(a, str(tmp_path))["meta"]
    assert meta["params_end"] == StateLayout(a).params_end
    assert meta["out_len"] == 2 + 2 * a.batch
    assert (tmp_path / "eval" / f"{a.eval_key}.hlo.txt").exists()


def test_registry_configs_are_loadable_and_consistent():
    models = load_models()
    variants = load_variants()
    assert "tiny-s" in models and "z5" in models
    for name, v in variants.items():
        assert v.model.name in models, name
        assert v.model.hidden % v.model.heads == 0, name
        assert v.model.head_dim % 2 == 0, f"{name}: RoPE needs even head_dim"
        assert v.optimizer in {"adamw", "sgd", "muon", "renorm", "spectron", "selfguided"}
        assert 0.0 < v.rank_ratio < 1.0
        # every variant must build a layout without error
        layout = StateLayout(v)
        assert layout.n_params > 0


def test_step_program_hlo_contains_while_loop_for_scan(tmp_path):
    """The scan-over-layers design keeps the HLO compact: depth shows up
    as a while loop, not unrolled layers."""
    small = variant(layers=2)
    big = variant(layers=5)
    from compile.programs import make_step

    def text_for(cfg):
        layout = StateLayout(cfg)
        lowered = jax.jit(make_step(layout, use_pallas=False)).lower(
            jax.ShapeDtypeStruct((layout.total,), jnp.float32),
            jax.ShapeDtypeStruct((cfg.batch, cfg.model.seq_len + 1), jnp.int32),
        )
        return to_hlo_text(lowered)

    t_small, t_big = text_for(small), text_for(big)
    assert "while" in t_big
    # compactness: 2.5x the layers must not cost 2x the HLO
    assert len(t_big) < 1.6 * len(t_small), (len(t_small), len(t_big))
