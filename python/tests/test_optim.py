"""Optimizer correctness — including the paper's core guarantee:

    ||dW||_2 = ||A'B'^T - AB^T||_2  <=  eta     (paper Eq. 11-16)

for Spectron updates, verified numerically on random factor pairs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import state as st
from compile.optim import (
    ADAM_B1,
    ADAM_B2,
    ADAM_EPS,
    alpha_schedule,
    lr_schedule,
    optimizer_step,
)
from compile.programs import _init_tensors
from compile.state import HDR, StateLayout

from .conftest import variant


def _header(step=10.0, total=100.0, lr=0.01, wd=0.0, warmup=0.05):
    h = np.zeros(HDR, np.float32)
    h[st.STEP] = step
    h[st.TOTAL_STEPS] = total
    h[st.BASE_LR] = lr
    h[st.WEIGHT_DECAY] = wd
    h[st.WARMUP_FRAC] = warmup
    return jnp.asarray(h)


def _setup(optimizer, wd=0.0, lr=0.01, step=50.0, **kw):
    cfg = variant(optimizer=optimizer, **kw)
    layout = StateLayout(cfg)
    tensors = _init_tensors(layout, jax.random.PRNGKey(0))
    # fake gradients: same scale as params
    keys = jax.random.split(jax.random.PRNGKey(1), 256)
    names = layout.param_names()
    if optimizer == "selfguided":
        names = names + [f"sg.{b}" for b in layout.factor_pairs()]
    grads = {
        n: 0.1 * jax.random.normal(keys[i], tensors[n].shape)
        for i, n in enumerate(names)
    }
    header = _header(step=step, lr=lr, wd=wd)
    return cfg, layout, tensors, grads, header


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------
def test_lr_schedule_shape():
    hs = [_header(step=s, total=100.0, lr=1.0, warmup=0.1) for s in range(100)]
    lrs = [float(lr_schedule(h)) for h in hs]
    assert lrs[0] == pytest.approx(0.1)  # (0+1)/10
    assert max(lrs) == pytest.approx(1.0)
    assert np.argmax(lrs) in range(8, 12)
    assert lrs[-1] < 0.002  # decays to ~0
    # monotone decreasing after warmup
    post = lrs[12:]
    assert all(a >= b - 1e-9 for a, b in zip(post, post[1:]))


def test_alpha_schedule_half_cosine():
    assert float(alpha_schedule(_header(step=0, total=100))) == pytest.approx(1.0)
    assert float(alpha_schedule(_header(step=25, total=100))) == pytest.approx(0.5, abs=1e-5)
    assert float(alpha_schedule(_header(step=50, total=100))) == pytest.approx(0.0, abs=1e-6)
    assert float(alpha_schedule(_header(step=80, total=100))) == 0.0


# ---------------------------------------------------------------------------
# the paper's spectral bound
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_spectron_bounds_composite_update(seed):
    """||A'B'^T - AB^T||_2 <= eta for every factor pair (Eq. 11)."""
    eta = 0.01
    cfg, layout, tensors, grads, header = _setup("spectron", lr=eta, step=60.0)
    keys = jax.random.split(jax.random.PRNGKey(seed + 10), 256)
    grads = {
        n: 2.0 * jax.random.normal(keys[i], g.shape)  # large, adversarial grads
        for i, (n, g) in enumerate(grads.items())
    }
    # warm the persisted power-iteration vectors so sigma estimates are tight
    cur = tensors
    for _ in range(3):
        cur, _ = optimizer_step(layout, cur, grads, header, use_pallas=False)
    new, info = optimizer_step(layout, cur, grads, header, use_pallas=False)
    eta_t = float(lr_schedule(header))
    for base in layout.factor_pairs():
        for lyr in range(cfg.model.layers):
            w0 = np.asarray(cur[f"{base}_a"][lyr] @ cur[f"{base}_b"][lyr].T)
            w1 = np.asarray(new[f"{base}_a"][lyr] @ new[f"{base}_b"][lyr].T)
            spec = np.linalg.svd(w1 - w0, compute_uv=False)[0]
            # NS singular values overshoot unity by up to ~1.3 (Jordan
            # coefficients), so the practical bound carries that factor.
            assert spec <= 1.4 * eta_t, (base, lyr, spec, eta_t)


def test_spectron_factor_update_norms_bounded_by_rho():
    cfg, layout, tensors, grads, header = _setup("spectron", lr=0.01)
    new, info = optimizer_step(layout, tensors, grads, header, use_pallas=False)
    rho = float(info["rho"])
    base = layout.factor_pairs()[0]
    lyr = cfg.model.layers // 2
    da = np.asarray(new[f"{base}_a"][lyr] - tensors[f"{base}_a"][lyr])
    assert np.linalg.svd(da, compute_uv=False)[0] <= 1.4 * rho


def test_adamw_matches_reference_formula():
    cfg, layout, tensors, grads, header = _setup("adamw", lr=0.01, wd=0.1, step=0.0)
    new, _ = optimizer_step(layout, tensors, grads, header)
    lr = float(lr_schedule(header))
    n = "rms_f"
    g = np.asarray(grads[n], np.float64)
    p = np.asarray(tensors[n], np.float64)
    m = (1 - ADAM_B1) * g
    v = (1 - ADAM_B2) * g * g
    mh, vh = m / (1 - ADAM_B1), v / (1 - ADAM_B2)
    want = p - lr * (mh / (np.sqrt(vh) + ADAM_EPS))  # rms_f: no weight decay
    np.testing.assert_allclose(np.asarray(new[n]), want, atol=1e-6)
    # weight-decayed tensor
    n = "embed"
    g = np.asarray(grads[n], np.float64)
    p = np.asarray(tensors[n], np.float64)
    m = (1 - ADAM_B1) * g
    v = (1 - ADAM_B2) * g * g
    mh, vh = m / (1 - ADAM_B1), v / (1 - ADAM_B2)
    want = p - lr * (mh / (np.sqrt(vh) + ADAM_EPS) + 0.1 * p)
    np.testing.assert_allclose(np.asarray(new[n]), want, atol=1e-6)


def test_muon_update_is_orthogonal():
    cfg, layout, tensors, grads, header = _setup("muon", lr=0.01, wd=0.0)
    new, _ = optimizer_step(layout, tensors, grads, header, use_pallas=False)
    lr = float(lr_schedule(header))
    n = layout.matrix_param_names()[0]
    delta = np.asarray(tensors[n][0] - new[n][0]) / lr
    s = np.linalg.svd(delta, compute_uv=False)
    assert s.max() < 1.35 and s.min() > 0.4, s


def test_sgd_momentum_rule():
    cfg, layout, tensors, grads, header = _setup("sgd", lr=0.1, wd=0.0)
    new, _ = optimizer_step(layout, tensors, grads, header)
    lr = float(lr_schedule(header))
    n = "embed"
    mom = 0.05 * np.asarray(grads[n])  # (1-beta)*g with zero init momentum
    np.testing.assert_allclose(
        np.asarray(new[n]), np.asarray(tensors[n]) - lr * mom, atol=1e-6
    )
    np.testing.assert_allclose(np.asarray(new[f"opt.mom.{n}"]), mom, atol=1e-7)


def test_renorm_constrains_update_without_ortho():
    cfg, layout, tensors, grads, header = _setup("renorm", lr=0.01)
    cur = tensors
    for _ in range(3):  # warm persisted vectors
        cur, _ = optimizer_step(layout, cur, grads, header, use_pallas=False)
    new, info = optimizer_step(layout, cur, grads, header, use_pallas=False)
    base = layout.factor_pairs()[0]
    lyr = cfg.model.layers // 2
    da = np.asarray(new[f"{base}_a"][lyr] - cur[f"{base}_a"][lyr])
    s = np.linalg.svd(da, compute_uv=False)
    assert s[0] <= 1.4 * float(info["rho"])
    # renorm only rescales the momentum — the update direction must stay
    # parallel to it (unlike Newton-Schulz, which reshapes the spectrum)
    mom = np.asarray(new[f"opt.mom.{base}_a"][lyr])
    cos = np.sum(da * -mom) / (np.linalg.norm(da) * np.linalg.norm(mom))
    assert cos > 0.999, cos


def test_selfguided_updates_aux_weights():
    cfg, layout, tensors, grads, header = _setup("selfguided", lr=0.01)
    new, _ = optimizer_step(layout, tensors, grads, header)
    base = layout.factor_pairs()[0]
    assert not np.allclose(
        np.asarray(new[f"sg.{base}"]), np.asarray(tensors[f"sg.{base}"])
    )


def test_weight_decay_shrinks_matrices():
    cfg, layout, t0, grads, header = _setup("spectron", lr=0.01, wd=0.5)
    zero_grads = {n: jnp.zeros_like(g) for n, g in grads.items()}
    new, _ = optimizer_step(layout, t0, zero_grads, header, use_pallas=False)
    n = "embed"
    assert float(jnp.linalg.norm(new[n])) < float(jnp.linalg.norm(t0[n]))
    # norm gains don't decay
    np.testing.assert_allclose(np.asarray(new["rms_f"]), np.asarray(t0["rms_f"]),
                               atol=1e-6)
