"""State layout: pack/unpack round-trips, manifests, optimizer sections."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import state as st
from compile.state import HDR, StateLayout, matrix_dims

from .conftest import variant


@pytest.mark.parametrize(
    "optimizer", ["adamw", "sgd", "muon", "renorm", "spectron", "selfguided"]
)
def test_pack_unpack_roundtrip(optimizer):
    layout = StateLayout(variant(optimizer=optimizer))
    key = jax.random.PRNGKey(0)
    state = jax.random.normal(key, (layout.total,))
    header, tensors = layout.unpack(state)
    repacked = layout.pack(header, tensors)
    np.testing.assert_array_equal(np.asarray(state), np.asarray(repacked))


def test_param_section_is_optimizer_independent():
    layouts = {
        o: StateLayout(variant(optimizer=o))
        for o in ["adamw", "sgd", "muon", "renorm", "spectron", "selfguided"]
    }
    ref = layouts["adamw"]
    for o, l in layouts.items():
        assert l.params_end == ref.params_end, o
        for n in ref.param_names():
            assert l.specs[n].offset == ref.specs[n].offset, (o, n)
            assert l.specs[n].shape == ref.specs[n].shape, (o, n)


def test_offsets_are_contiguous_and_disjoint():
    layout = StateLayout(variant(optimizer="spectron"))
    cursor = HDR
    for spec in layout.specs.values():
        assert spec.offset == cursor
        cursor += spec.size
    assert cursor == layout.total


def test_rank_rounding():
    cfg = variant(rank_ratio=0.25, hidden=64)
    assert cfg.rank(64) == 16
    assert cfg.rank(100) == 24  # rounded to multiple of 8
    assert cfg.rank(8) == 8  # floor at 8


def test_factor_pair_shapes_follow_paper():
    """W (m x n) -> A (m x r), B (n x r), r = ratio * n (input dim)."""
    cfg = variant(optimizer="spectron", hidden=64)
    layout = StateLayout(cfg)
    for mat in ("attn_q", "ffn_gate", "ffn_down"):
        m, n = matrix_dims(cfg, mat)
        r = cfg.rank(n)
        assert layout.specs[f"{mat}_a"].shape == (cfg.model.layers, m, r)
        assert layout.specs[f"{mat}_b"].shape == (cfg.model.layers, n, r)


def test_manifest_contents():
    cfg = variant(optimizer="spectron")
    layout = StateLayout(cfg)
    man = layout.manifest()
    assert man["state_len"] == layout.total
    assert man["hdr"] == HDR
    assert man["n_params"] == layout.params_end - HDR
    names = {t["name"] for t in man["tensors"]}
    assert "embed" in names and "attn_q_a" in names and "opt.mom.attn_q_a" in names
    total = HDR + sum(int(np.prod(t["shape"])) for t in man["tensors"])
    assert total == man["state_len"]


def test_selfguided_has_dense_aux_per_pair():
    cfg = variant(optimizer="selfguided")
    layout = StateLayout(cfg)
    for base in layout.factor_pairs():
        m, n = matrix_dims(cfg, base)
        assert layout.specs[f"sg.{base}"].shape == (cfg.model.layers, m, n)


def test_ffn_only_factorization_splits_correctly():
    cfg = variant(factorize="ffn")
    layout = StateLayout(cfg)
    assert "attn_q" in layout.specs and "attn_q_a" not in layout.specs
    assert "ffn_gate_a" in layout.specs and "ffn_gate" not in layout.specs
    assert layout.factor_pairs() == ["ffn_gate", "ffn_up", "ffn_down"]


def test_header_slots_distinct():
    slots = [
        st.STEP, st.TOTAL_STEPS, st.BASE_LR, st.WEIGHT_DECAY, st.WARMUP_FRAC,
        st.LOSS, st.LR, st.GRAD_NORM, st.W_SPEC, st.DW_SPEC, st.DY_RMS,
        st.SIGMA_A, st.SIGMA_B, st.RHO, st.ALPHA, st.TOKENS_SEEN,
    ]
    assert len(set(slots)) == len(slots)
    assert max(slots) < st.RING_BASE
    assert st.RING_BASE + st.RING == HDR
