"""In-graph spectral telemetry vs numpy ground truth."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.programs import _init_tensors
from compile.state import StateLayout
from compile.telemetry import spectral_telemetry, tracked_ops, _spectral_norm

from .conftest import variant


def test_tracked_ops_factored_matches_dense_product():
    cfg = variant(optimizer="spectron")
    layout = StateLayout(cfg)
    tensors = _init_tensors(layout, jax.random.PRNGKey(0))
    lyr = cfg.model.layers // 2
    mv, mt, n = tracked_ops(layout, tensors, "attn_o", lyr)
    a = np.asarray(tensors["attn_o_a"][lyr])
    b = np.asarray(tensors["attn_o_b"][lyr])
    w = a @ b.T
    x = np.random.default_rng(0).normal(size=n).astype(np.float32)
    np.testing.assert_allclose(np.asarray(mv(jnp.asarray(x))), w @ x, atol=1e-4)
    y = np.random.default_rng(1).normal(size=w.shape[0]).astype(np.float32)
    np.testing.assert_allclose(np.asarray(mt(jnp.asarray(y))), w.T @ y, atol=1e-4)


def test_spectral_norm_power_iteration_accuracy():
    rng = np.random.default_rng(2)
    w = rng.normal(size=(48, 32)).astype(np.float32)
    # boost the top direction for a clean spectral gap
    u, s, vt = np.linalg.svd(w, full_matrices=False)
    s[0] *= 3.0
    w = (u * s) @ vt
    wj = jnp.asarray(w)
    est = _spectral_norm(
        lambda x: wj @ x, lambda y: wj.T @ y, 32, jax.random.PRNGKey(0)
    )
    assert abs(float(est) - s[0]) / s[0] < 0.01


def test_spectral_telemetry_detects_known_update():
    """Plant a rank-1 update of known spectral norm in the tracked pair and
    check dw_spec reports it."""
    cfg = variant(optimizer="spectron")
    layout = StateLayout(cfg)
    old = _init_tensors(layout, jax.random.PRNGKey(0))
    new = dict(old)
    lyr = cfg.model.layers // 2
    a = old["attn_o_a"]
    # bump one column of A by delta: dW = (delta e_col) B^T
    delta = 0.05
    new["attn_o_a"] = a.at[lyr, :, 0].add(delta * jnp.ones(a.shape[1]))
    w_spec, dw_spec, dy_rms = spectral_telemetry(layout, old, new, jnp.float32(3))
    b0 = np.asarray(old["attn_o_b"][lyr])
    dw_true = np.linalg.svd(
        np.outer(delta * np.ones(a.shape[1]), b0[:, 0]), compute_uv=False
    )[0]
    assert abs(float(dw_spec) - dw_true) / dw_true < 0.05, (float(dw_spec), dw_true)
    assert float(w_spec) > 0.1
    assert float(dy_rms) > 0.0


def test_telemetry_zero_update_reports_zero():
    cfg = variant(optimizer="spectron")
    layout = StateLayout(cfg)
    t = _init_tensors(layout, jax.random.PRNGKey(0))
    _, dw_spec, dy_rms = spectral_telemetry(layout, t, dict(t), jnp.float32(0))
    assert float(dw_spec) < 1e-6
    assert float(dy_rms) < 1e-6


def test_telemetry_dense_variant():
    cfg = variant(optimizer="muon", factorize="none")
    layout = StateLayout(cfg)
    old = _init_tensors(layout, jax.random.PRNGKey(0))
    new = dict(old)
    lyr = cfg.model.layers // 2
    new["attn_o"] = old["attn_o"].at[lyr].add(0.01)
    w_spec, dw_spec, _ = spectral_telemetry(layout, old, new, jnp.float32(1))
    d = cfg.model.hidden
    # dW = 0.01 * ones(d,d) -> spectral norm 0.01*d
    assert abs(float(dw_spec) - 0.01 * d) / (0.01 * d) < 0.05
    assert float(w_spec) > 0.0
