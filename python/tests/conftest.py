"""Shared fixtures: small architectures so the suite stays fast."""

import jax
import pytest

from compile.config import ModelCfg, VariantCfg

jax.config.update("jax_enable_x64", False)


def tiny_model(hidden=64, layers=2, heads=2, vocab=128, seq_len=32) -> ModelCfg:
    return ModelCfg(
        name="test", hidden=hidden, layers=layers, heads=heads, vocab=vocab,
        seq_len=seq_len,
    )


def variant(
    optimizer="spectron",
    factorize="all",
    rank_ratio=0.25,
    batch=2,
    telemetry=True,
    programs=("init", "step", "eval", "grad", "apply"),
    **model_kw,
) -> VariantCfg:
    return VariantCfg(
        name=f"test-{optimizer}-{factorize}",
        model=tiny_model(**model_kw),
        factorize=factorize,
        rank_ratio=rank_ratio,
        optimizer=optimizer,
        batch=batch,
        telemetry=telemetry,
        telemetry_matrix="attn_o",
        emb_lr_mult=0.3,
        programs=tuple(programs),
    )


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)
