"""In-graph spectral telemetry (reproduces the paper's Figures 2 and 3).

Tracks, for one configured matrix (default: the middle layer's attention
output projection, the paper tracks layer 4's), three quantities per step:

* ``||W||_2``   — spectral norm of the current (product) weight,
* ``||dW||_2``  — spectral norm of the composite weight update
                  dW = A'B'ᵀ - ABᵀ (paper Eq. 2),
* ``|dy|_rms``  — RMS activation change for a unit-RMS probe (Eq. 9-10).

For factorized layers the product matrix is never materialized: power
iteration runs on the matvec pair x -> A(Bᵀx), exactly the trick the
optimizer itself uses. Results land in state-header slots so the Rust
trainer reads them with the ordinary state readback.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import VariantCfg
from .state import StateLayout, is_factorized

POWER_ITERS = 8  # more than the optimizer's k=1: these are *measurements*


def _spectral_norm(matvec, matvec_t, n: int, key) -> jnp.ndarray:
    """Power iteration on an implicit linear operator R^n -> R^m."""
    v = jax.random.normal(key, (n,), jnp.float32)
    v = v / (jnp.linalg.norm(v) + 1e-20)
    for _ in range(POWER_ITERS):
        u = matvec(v)
        u = u / (jnp.linalg.norm(u) + 1e-20)
        v = matvec_t(u)
        nv = jnp.linalg.norm(v)
        v = v / (nv + 1e-20)
    return nv


def tracked_ops(layout: StateLayout, tensors: dict, mat: str, lyr: int):
    """(matvec, matvec_t, n) for the tracked matrix in `tensors`."""
    cfg = layout.cfg
    if is_factorized(cfg, mat):
        a = tensors[f"{mat}_a"][lyr]  # (m, r)
        b = tensors[f"{mat}_b"][lyr]  # (n, r)
        return (lambda x: a @ (b.T @ x)), (lambda y: b @ (a.T @ y)), b.shape[0]
    w = tensors[mat][lyr]  # (m, n)
    return (lambda x: w @ x), (lambda y: w.T @ y), w.shape[1]


def spectral_telemetry(
    layout: StateLayout, old: dict, new: dict, step: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (w_spec, dw_spec, dy_rms) for the tracked matrix."""
    cfg: VariantCfg = layout.cfg
    mat = cfg.telemetry_matrix
    lyr = cfg.model.layers // 2
    key = jax.random.fold_in(jax.random.PRNGKey(1234), step.astype(jnp.int32))
    k_w, k_dw, k_probe = jax.random.split(key, 3)

    mv1, mt1, n = tracked_ops(layout, new, mat, lyr)
    mv0, mt0, _ = tracked_ops(layout, old, mat, lyr)
    dmv = lambda x: mv1(x) - mv0(x)
    dmt = lambda y: mt1(y) - mt0(y)

    w_spec = _spectral_norm(mv1, mt1, n, k_w)
    dw_spec = _spectral_norm(dmv, dmt, n, k_dw)

    # |dy|_rms for a unit-RMS probe x: dy = dW x   (paper Eq. 9)
    x = jax.random.normal(k_probe, (n,), jnp.float32)
    x = x / (jnp.sqrt(jnp.mean(x * x)) + 1e-20)
    dy = dmv(x)
    dy_rms = jnp.sqrt(jnp.mean(dy * dy))
    return w_spec, dw_spec, dy_rms
