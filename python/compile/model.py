"""L2: LLaMA-style transformer with native low-rank factorized weights.

Architecture follows the paper's Appendix E: RMSNorm pre-norm, RoPE
attention, SwiGLU FFN, untied embedding/head, no biases. Every
non-embedding matrix can be parameterized as W = A Bᵀ (factorize="all"),
only the FFN matrices (factorize="ffn", the Wei et al. 2024a setting), or
kept dense (factorize="none").

Layer parameters are stored stacked along a leading layer axis and the
block is applied with ``lax.scan`` — this keeps the lowered HLO compact
(one layer body regardless of depth) and lets the optimizer vmap the
Newton-Schulz kernel across layers.

Python here runs at build time only: ``aot.py`` lowers the jitted step
functions to HLO text consumed by the Rust runtime.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .config import VariantCfg
from .kernels import lowrank_matmul
from .state import MATRIX_NAMES, is_factorized


def rms_norm(x: jnp.ndarray, gain: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    return x * lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * gain


def rope_tables(seq_len: int, head_dim: int, base: float = 10000.0):
    """Precompute RoPE cos/sin tables (seq, head_dim/2)."""
    half = head_dim // 2
    freqs = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = jnp.arange(seq_len, dtype=jnp.float32)[:, None] * freqs[None, :]
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (B, T, H, hd) -> rotated pairs (Su et al. 2024)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[None, :, None, :]
    s = sin[None, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)


def apply_matrix(
    x: jnp.ndarray,
    lp: dict,
    mat: str,
    cfg: VariantCfg,
    alpha=None,
    use_pallas_matmul: bool = False,
) -> jnp.ndarray:
    """y = W x for one per-layer matrix (factorized or dense).

    ``lp`` holds this layer's tensors. When ``alpha`` is given and a
    self-guided auxiliary dense weight ``sg.<mat>`` is present, the output
    mixes o = alpha * W_aux x + (1 - alpha) * A Bᵀ x  (paper Eq. 17).
    """
    if is_factorized(cfg, mat):
        a, b = lp[f"{mat}_a"], lp[f"{mat}_b"]
        if use_pallas_matmul:
            flat = x.reshape(-1, x.shape[-1])
            y = lowrank_matmul(flat, a, b).reshape(*x.shape[:-1], a.shape[0])
        else:
            y = (x @ b) @ a.T
        if alpha is not None and f"sg.{mat}" in lp:
            y = alpha * (x @ lp[f"sg.{mat}"].T) + (1.0 - alpha) * y
        return y
    return x @ lp[mat].T


def layer_tensors(tensors: dict, cfg: VariantCfg) -> dict:
    """Collect the stacked per-layer tensors (leading layer axis)."""
    out = {}
    for mat in MATRIX_NAMES:
        if is_factorized(cfg, mat):
            out[f"{mat}_a"] = tensors[f"{mat}_a"]
            out[f"{mat}_b"] = tensors[f"{mat}_b"]
            if f"sg.{mat}" in tensors:
                out[f"sg.{mat}"] = tensors[f"sg.{mat}"]
        else:
            out[mat] = tensors[mat]
    out["rms1"] = tensors["rms1"]
    out["rms2"] = tensors["rms2"]
    return out


def forward(
    tensors: dict,
    tokens: jnp.ndarray,
    cfg: VariantCfg,
    alpha=None,
    use_pallas_matmul: bool = False,
) -> jnp.ndarray:
    """tokens (B, T) int32 -> logits (B, T, V). Causal."""
    m = cfg.model
    bsz, seq = tokens.shape
    h = tensors["embed"][tokens]  # (B, T, d)
    cos, sin = rope_tables(seq, m.head_dim)
    causal = jnp.tril(jnp.ones((seq, seq), jnp.bool_))

    def block(h, lp):
        n1 = rms_norm(h, lp["rms1"])
        q = apply_matrix(n1, lp, "attn_q", cfg, alpha, use_pallas_matmul)
        k = apply_matrix(n1, lp, "attn_k", cfg, alpha, use_pallas_matmul)
        v = apply_matrix(n1, lp, "attn_v", cfg, alpha, use_pallas_matmul)
        q = apply_rope(q.reshape(bsz, seq, m.heads, m.head_dim), cos, sin)
        k = apply_rope(k.reshape(bsz, seq, m.heads, m.head_dim), cos, sin)
        v = v.reshape(bsz, seq, m.heads, m.head_dim)
        scores = jnp.einsum("bthe,bshe->bhts", q, k) / jnp.sqrt(
            jnp.asarray(m.head_dim, jnp.float32)
        )
        scores = jnp.where(causal[None, None, :, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhts,bshe->bthe", probs, v).reshape(bsz, seq, m.hidden)
        h = h + apply_matrix(ctx, lp, "attn_o", cfg, alpha, use_pallas_matmul)

        n2 = rms_norm(h, lp["rms2"])
        gate = apply_matrix(n2, lp, "ffn_gate", cfg, alpha, use_pallas_matmul)
        up = apply_matrix(n2, lp, "ffn_up", cfg, alpha, use_pallas_matmul)
        inner = jax.nn.silu(gate) * up
        h = h + apply_matrix(inner, lp, "ffn_down", cfg, alpha, use_pallas_matmul)
        return h, None

    stacked = layer_tensors(tensors, cfg)
    h, _ = lax.scan(block, h, stacked)
    h = rms_norm(h, tensors["rms_f"])
    return h @ tensors["head"].T


def token_nll(logits: jnp.ndarray, targets: jnp.ndarray) -> jnp.ndarray:
    """Per-token next-token NLL, (B, T)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return logz - gold


def loss_fn(
    tensors: dict, tokens: jnp.ndarray, cfg: VariantCfg, alpha=None
) -> jnp.ndarray:
    """Mean next-token cross-entropy over a packed (B, T+1) batch."""
    logits = forward(tensors, tokens[:, :-1], cfg, alpha)
    return jnp.mean(token_nll(logits, tokens[:, 1:]))


def span_scores(tensors: dict, tokens: jnp.ndarray, spans: jnp.ndarray, cfg: VariantCfg):
    """Per-sequence NLL restricted to a span (for eval + downstream scoring).

    tokens: (B, T+1) padded; spans: (B, 2) int32 [start, end) over token
    positions — position i is *scored* when start <= i < end-1, i.e. the
    model predicts tokens[i+1]. Returns (per_seq_nll, per_seq_count).
    """
    logits = forward(tensors, tokens[:, :-1], cfg)
    nll = token_nll(logits, tokens[:, 1:])  # (B, T)
    pos = jnp.arange(nll.shape[1], dtype=jnp.int32)[None, :]
    mask = (pos >= spans[:, :1]) & (pos < spans[:, 1:2] - 1)
    maskf = mask.astype(jnp.float32)
    return jnp.sum(nll * maskf, axis=1), jnp.sum(maskf, axis=1)
