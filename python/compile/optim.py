"""L2 optimizers, lowered into the train-step HLO.

Implements the paper's Algorithm 1 (Spectron) plus every baseline the
evaluation compares against:

* ``adamw``     — naive AdamW on all tensors (Kingma & Ba), the paper's
                  "Naive" baseline.
* ``sgd``       — momentum SGD (the naive baseline of the Table 2 ablation).
* ``muon``      — Newton-Schulz orthogonalized momentum on matrices
                  (Jordan et al. 2024): the "orthogonalization only"
                  ablation row; also used for the dense baselines.
* ``renorm``    — spectral renormalization only: momentum normalized to
                  unit spectral norm, scaled by the adaptive radius
                  rho = eta / (sigma_A + sigma_B + 1)  (ablation row 2).
* ``spectron``  — Algorithm 1: ortho + renorm. Guarantees
                  ||dW||_2 <= eta (paper Eq. 13-16).
* ``selfguided``— Wei et al. 2024a (Appendix C): dense auxiliary weights
                  with cosine-decayed mixing, AdamW on everything.

Non-matrix tensors (embeddings, norms, lm head) always use AdamW — the
paper factorizes only non-embedding matrices; the AdamW lr is scaled by
``emb_lr_mult`` when the matrix optimizer is not AdamW (standard Muon
practice).

All hyper-knobs that the paper sweeps (base lr, weight decay, total steps,
warmup) live in the state header, written by the Rust runtime at init, so
one lowered program serves every configuration.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import state as st
from .config import VariantCfg
from .kernels import newton_schulz, power_iter
from .state import StateLayout

ADAM_B1 = 0.9
ADAM_B2 = 0.95
ADAM_EPS = 1e-8
MOMENTUM = 0.95  # paper Algorithm 1 suggests 0.9 or 0.95; Muon uses 0.95
K_NS = 5  # Newton-Schulz iterations (paper default)
K_POWER = 1  # power-iteration steps per optimizer step (paper default)


def lr_schedule(header: jnp.ndarray) -> jnp.ndarray:
    """Cosine-to-zero with linear warmup (paper Appendix E.3)."""
    t = header[st.STEP]
    total = jnp.maximum(header[st.TOTAL_STEPS], 1.0)
    base = header[st.BASE_LR]
    warm = jnp.maximum(header[st.WARMUP_FRAC] * total, 1.0)
    # clip: with fractional warm the last warmup step could overshoot base
    warm_lr = jnp.minimum((t + 1.0) / warm, 1.0)
    prog = jnp.clip((t - warm) / jnp.maximum(total - warm, 1.0), 0.0, 1.0)
    cos_lr = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return base * jnp.where(t < warm, warm_lr, cos_lr)


def alpha_schedule(header: jnp.ndarray) -> jnp.ndarray:
    """Self-guided mixing: cosine 1 -> 0 across the first half of training
    (Wei et al. 2024a), 0 afterwards."""
    t = header[st.STEP]
    half = jnp.maximum(0.5 * header[st.TOTAL_STEPS], 1.0)
    prog = jnp.clip(t / half, 0.0, 1.0)
    return 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def _adamw_update(p, g, m, v, t, lr, wd):
    m = ADAM_B1 * m + (1.0 - ADAM_B1) * g
    v = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
    mhat = m / (1.0 - ADAM_B1 ** (t + 1.0))
    vhat = v / (1.0 - ADAM_B2 ** (t + 1.0))
    p = p - lr * (mhat / (jnp.sqrt(vhat) + ADAM_EPS) + wd * p)
    return p, m, v


def _decay(name: str) -> float:
    """Decoupled weight decay applies to matrices/embeddings, not norms."""
    return 0.0 if name.startswith("rms") else 1.0


def optimizer_step(
    layout: StateLayout,
    tensors: dict,
    grads: dict,
    header: jnp.ndarray,
    use_pallas: bool = True,
) -> tuple[dict, dict]:
    """Apply one optimizer step in-graph.

    ``tensors`` holds params + opt slots (all entries of the layout);
    ``grads`` holds gradients for every trainable tensor (params, plus
    ``sg.*`` auxiliaries for self-guided). Returns (new_tensors, info)
    where info carries telemetry scalars (sigma_a, sigma_b, rho).
    """
    cfg: VariantCfg = layout.cfg
    opt = cfg.optimizer
    t = header[st.STEP]
    lr = lr_schedule(header)
    wd = header[st.WEIGHT_DECAY]
    new = dict(tensors)
    info = {
        "sigma_a": jnp.float32(0.0),
        "sigma_b": jnp.float32(0.0),
        "rho": lr,
        "lr": lr,
    }

    def adamw_all(names, lr_eff):
        for n in names:
            p, g = tensors[n], grads[n]
            m, v = tensors[f"opt.m.{n}"], tensors[f"opt.v.{n}"]
            p, m, v = _adamw_update(p, g, m, v, t, lr_eff, wd * _decay(n))
            new[n], new[f"opt.m.{n}"], new[f"opt.v.{n}"] = p, m, v

    if opt in ("adamw", "selfguided"):
        trainable = layout.param_names()
        if opt == "selfguided":
            trainable = trainable + [f"sg.{b}" for b in layout.factor_pairs()]
        adamw_all(trainable, lr)
        return new, info

    if opt == "sgd":
        for n in layout.param_names():
            p, g = tensors[n], grads[n]
            mom = MOMENTUM * tensors[f"opt.mom.{n}"] + (1.0 - MOMENTUM) * g
            new[f"opt.mom.{n}"] = mom
            new[n] = p - lr * mom - lr * wd * _decay(n) * p
        return new, info

    # ---- matrix optimizers: muon / renorm / spectron ----
    mats = layout.matrix_param_names()
    others = [n for n in layout.param_names() if n not in mats]
    adamw_all(others, lr * cfg.emb_lr_mult)

    # momentum for every matrix tensor (stacked [layers, m, r|n])
    moms = {}
    for n in mats:
        mom = MOMENTUM * tensors[f"opt.mom.{n}"] + (1.0 - MOMENTUM) * grads[n]
        new[f"opt.mom.{n}"] = mom
        moms[n] = mom

    if opt == "muon":
        # paper Eq. (8): theta <- theta - eta * Ortho(M)
        for n in mats:
            o = newton_schulz(moms[n], K_NS, use_pallas=use_pallas)
            new[n] = tensors[n] - lr * o - lr * wd * tensors[n]
        return new, info

    # spectron / renorm operate on factor *pairs* with a shared radius
    # rho = eta / (sigma_A + sigma_B + 1)   (paper Eq. 16)
    pairs = layout.factor_pairs()
    paired = {f"{b}_{s}" for b in pairs for s in ("a", "b")}
    # dense matrices in "ffn"-factorize mode still need an update rule:
    # they get the plain Muon rule (only factor pairs need the radius).
    for n in mats:
        if n not in paired:
            o = newton_schulz(moms[n], K_NS, use_pallas=use_pallas)
            new[n] = tensors[n] - lr * o - lr * wd * tensors[n]

    sig_a_first = sig_b_first = rho_first = None
    for base in pairs:
        na, nb = f"{base}_a", f"{base}_b"
        a_t, b_t = tensors[na], tensors[nb]
        # sigma estimates with persisted left vectors (Algorithm 3)
        sa, ua = power_iter(a_t, tensors[f"opt.u.{na}"], K_POWER, use_pallas=use_pallas)
        sb, ub = power_iter(b_t, tensors[f"opt.u.{nb}"], K_POWER, use_pallas=use_pallas)
        new[f"opt.u.{na}"], new[f"opt.u.{nb}"] = ua, ub
        rho = lr / (sa + sb + 1.0)  # (layers,)
        rho3 = rho[:, None, None]

        if opt == "spectron":
            oa = newton_schulz(moms[na], K_NS, use_pallas=use_pallas)
            ob = newton_schulz(moms[nb], K_NS, use_pallas=use_pallas)
        else:  # renorm: normalize momentum to unit spectral norm instead
            sma, uma = power_iter(
                moms[na], tensors[f"opt.um.{na}"], 2, use_pallas=use_pallas
            )
            smb, umb = power_iter(
                moms[nb], tensors[f"opt.um.{nb}"], 2, use_pallas=use_pallas
            )
            new[f"opt.um.{na}"], new[f"opt.um.{nb}"] = uma, umb
            oa = moms[na] / (jnp.abs(sma)[:, None, None] + 1e-8)
            ob = moms[nb] / (jnp.abs(smb)[:, None, None] + 1e-8)

        new[na] = a_t - rho3 * oa - lr * wd * a_t
        new[nb] = b_t - rho3 * ob - lr * wd * b_t

        if base == cfg.telemetry_matrix or sig_a_first is None:
            mid = cfg.model.layers // 2
            sig_a_first, sig_b_first, rho_first = sa[mid], sb[mid], rho[mid]

    if sig_a_first is not None:
        info["sigma_a"], info["sigma_b"], info["rho"] = (
            sig_a_first,
            sig_b_first,
            rho_first,
        )
    return new, info
