"""Program builders: the jax functions that aot.py lowers to HLO text.

Every program obeys the single-flat-f32-output convention (DESIGN.md):

* ``init(seed i32[], knobs f32[8])      -> state f32[L]``
* ``step(state f32[L], tokens i32[B,T+1]) -> state' f32[L]``
* ``eval(prefix f32[P], tokens i32[B,T+1], spans i32[B,2]) -> f32[2+2B]``
* ``grad(state f32[L], tokens i32[B,T+1]) -> f32[1+NP]  ([loss | grads])``
* ``apply(state f32[L], gradvec f32[1+NP]) -> state' f32[L]``
* ``logits(prefix f32[P], tokens i32[B,T], pos i32[B]) -> f32[B*V]``

``eval`` and ``logits`` take only the header+params prefix of the state so
that one program per architecture is shared by every optimizer. ``grad``
and ``apply`` split the train step for the coordinator's gradient
accumulation and simulated data-parallel all-reduce. ``logits`` is the
serving decode step (DESIGN.md §Serving): next-token logits at one
position per sequence, flattened row-major to keep the single-output
convention.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import state as st
from .config import VariantCfg
from .kernels import newton_schulz
from .model import forward, loss_fn, span_scores
from .optim import alpha_schedule, optimizer_step
from .state import HDR, RING, RING_BASE, StateLayout, is_factorized, matrix_dims
from .telemetry import spectral_telemetry


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_tensors(layout: StateLayout, key) -> dict:
    """Parameter init. Factorized matrices use Newton-Schulz orthogonalized
    factors scaled so that ||A Bᵀ||_2 matches the spectral norm of the dense
    init — an SVD-free stand-in for Khodak et al.'s spectral initialization
    (no LAPACK custom-calls survive in the lowered HLO; see DESIGN.md
    substitutions)."""
    cfg = layout.cfg
    m = cfg.model
    n_res = 2.0 * m.layers  # residual-branch variance scaling (GPT-2 style)
    tensors = {}
    keys = iter(jax.random.split(key, 64))

    tensors["embed"] = 0.02 * jax.random.normal(next(keys), (m.vocab, m.hidden))
    tensors["head"] = (1.0 / jnp.sqrt(m.hidden)) * jax.random.normal(
        next(keys), (m.vocab, m.hidden)
    )
    tensors["rms1"] = jnp.ones((m.layers, m.hidden), jnp.float32)
    tensors["rms2"] = jnp.ones((m.layers, m.hidden), jnp.float32)
    tensors["rms_f"] = jnp.ones((m.hidden,), jnp.float32)

    for mat in st.MATRIX_NAMES:
        om, on = matrix_dims(cfg, mat)
        res_scale = 1.0 / jnp.sqrt(n_res) if mat in ("attn_o", "ffn_down") else 1.0
        if is_factorized(cfg, mat):
            r = cfg.rank(on)
            # dense-init spectral norm estimate for iid N(0, 1/n) entries
            sigma_tgt = (jnp.sqrt(om * 1.0) + jnp.sqrt(on * 1.0)) / jnp.sqrt(on * 1.0)
            sa = jnp.sqrt(sigma_tgt) * res_scale
            ga = jax.random.normal(next(keys), (m.layers, om, r))
            gb = jax.random.normal(next(keys), (m.layers, on, r))
            tensors[f"{mat}_a"] = sa * newton_schulz(ga, use_pallas=False)
            tensors[f"{mat}_b"] = jnp.sqrt(sigma_tgt) * newton_schulz(
                gb, use_pallas=False
            )
        else:
            std = res_scale / jnp.sqrt(on * 1.0)
            tensors[mat] = std * jax.random.normal(next(keys), (m.layers, om, on))

    # optimizer section
    for name in layout.opt_names():
        spec = layout.specs[name]
        if name.startswith("opt.u"):  # power-iteration vectors: unit random
            v = jax.random.normal(next(keys), spec.shape)
            tensors[name] = v / (jnp.linalg.norm(v, axis=-1, keepdims=True) + 1e-20)
        elif name.startswith("sg."):  # self-guided aux: W0 = A0 B0ᵀ (Eq. 18)
            base = name[3:]
            a, b = tensors[f"{base}_a"], tensors[f"{base}_b"]
            tensors[name] = jnp.einsum("lmr,lnr->lmn", a, b)
        else:
            tensors[name] = jnp.zeros(spec.shape, jnp.float32)
    return tensors


def make_init(layout: StateLayout):
    def init(seed: jnp.ndarray, knobs: jnp.ndarray) -> jnp.ndarray:
        key = jax.random.PRNGKey(seed)
        tensors = _init_tensors(layout, key)
        header = jnp.zeros((HDR,), jnp.float32)
        # knobs = [total_steps, base_lr, weight_decay, warmup_frac, ...]
        header = header.at[st.TOTAL_STEPS].set(knobs[0])
        header = header.at[st.BASE_LR].set(knobs[1])
        header = header.at[st.WEIGHT_DECAY].set(knobs[2])
        header = header.at[st.WARMUP_FRAC].set(knobs[3])
        return layout.pack(header, tensors)

    return init


# ---------------------------------------------------------------------------
# shared pieces
# ---------------------------------------------------------------------------
def _trainable_names(layout: StateLayout) -> list[str]:
    names = layout.param_names()
    if layout.cfg.optimizer == "selfguided":
        names = names + [f"sg.{b}" for b in layout.factor_pairs()]
    return names


def _compute_grads(layout: StateLayout, tensors: dict, tokens, header):
    cfg = layout.cfg
    alpha = alpha_schedule(header) if cfg.optimizer == "selfguided" else None
    trainable = {n: tensors[n] for n in _trainable_names(layout)}

    def lf(tr):
        merged = {**tensors, **tr}
        return loss_fn(merged, tokens, cfg, alpha)

    loss, grads = jax.value_and_grad(lf)(trainable)
    gnorm = jnp.sqrt(
        sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in grads.values())
    )
    return loss, grads, gnorm, alpha


def _finish_header(layout, header, loss, gnorm, info, alpha, batch_tokens):
    t = header[st.STEP]
    h = header
    h = h.at[st.STEP].set(t + 1.0)
    h = h.at[st.LOSS].set(loss)
    h = h.at[st.LR].set(info["lr"])
    h = h.at[st.GRAD_NORM].set(gnorm)
    h = h.at[st.SIGMA_A].set(info["sigma_a"])
    h = h.at[st.SIGMA_B].set(info["sigma_b"])
    h = h.at[st.RHO].set(info["rho"])
    h = h.at[st.ALPHA].set(alpha if alpha is not None else 0.0)
    h = h.at[st.TOKENS_SEEN].set(header[st.TOKENS_SEEN] + batch_tokens)
    ring_idx = RING_BASE + jnp.mod(t.astype(jnp.int32), RING)
    h = jax.lax.dynamic_update_slice(h, loss[None], (ring_idx,))
    return h


def _apply_update(layout, tensors, grads, header, loss, gnorm, alpha, use_pallas):
    cfg = layout.cfg
    new_tensors, info = optimizer_step(layout, tensors, grads, header, use_pallas)
    if cfg.telemetry:
        w_spec, dw_spec, dy_rms = spectral_telemetry(
            layout, tensors, new_tensors, header[st.STEP]
        )
    else:
        w_spec = dw_spec = dy_rms = jnp.float32(0.0)
    batch_tokens = jnp.float32(cfg.batch * cfg.model.seq_len)
    h = _finish_header(layout, header, loss, gnorm, info, alpha, batch_tokens)
    h = h.at[st.W_SPEC].set(w_spec)
    h = h.at[st.DW_SPEC].set(dw_spec)
    h = h.at[st.DY_RMS].set(dy_rms)
    return layout.pack(h, new_tensors)


# ---------------------------------------------------------------------------
# step / grad / apply / eval
# ---------------------------------------------------------------------------
def make_step(layout: StateLayout, use_pallas: bool = True):
    def step(state: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
        header, tensors = layout.unpack(state)
        loss, grads, gnorm, alpha = _compute_grads(layout, tensors, tokens, header)
        return _apply_update(
            layout, tensors, grads, header, loss, gnorm, alpha, use_pallas
        )

    return step


def make_grad(layout: StateLayout):
    """[loss | flat grads] for the coordinator's microbatching/all-reduce."""
    assert layout.cfg.optimizer != "selfguided", "grad program: params-only"

    def grad(state: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
        header, tensors = layout.unpack(state)
        loss, grads, _gnorm, _ = _compute_grads(layout, tensors, tokens, header)
        parts = [loss[None]]
        for n in layout.param_names():
            parts.append(grads[n].reshape(-1).astype(jnp.float32))
        return jnp.concatenate(parts)

    return grad


def make_apply(layout: StateLayout, use_pallas: bool = True):
    def apply(state: jnp.ndarray, gradvec: jnp.ndarray) -> jnp.ndarray:
        header, tensors = layout.unpack(state)
        loss = gradvec[0]
        grads = {}
        off = 1
        for n in layout.param_names():
            spec = layout.specs[n]
            grads[n] = gradvec[off : off + spec.size].reshape(spec.shape)
            off += spec.size
        gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads.values()))
        return _apply_update(
            layout, tensors, grads, header, loss, gnorm, None, use_pallas
        )

    return apply


def make_eval(layout: StateLayout):
    """Shared per-(model, factorize, rank): takes the header+params prefix."""
    cfg = layout.cfg

    def evaluate(prefix: jnp.ndarray, tokens: jnp.ndarray, spans: jnp.ndarray):
        _header, tensors = _unpack_params_only(layout, prefix)
        nll, cnt = span_scores(tensors, tokens, spans, cfg)
        total = jnp.stack([jnp.sum(nll), jnp.sum(cnt)])
        return jnp.concatenate([total, nll, cnt])

    return evaluate


def make_logits(layout: StateLayout):
    """Serving decode step: next-token logits at ``pos[i]`` for sequence i.

    Shares the header+params prefix with ``eval`` (one program per
    architecture, reused across optimizers and checkpoints). ``tokens`` is
    the full (B, seq_len) decode window, PAD beyond each sequence's
    current length; causal attention makes the padding inert. The (B, V)
    logit rows are flattened row-major so the program keeps the
    single-flat-f32-output convention.
    """
    cfg = layout.cfg

    def logits(prefix: jnp.ndarray, tokens: jnp.ndarray, pos: jnp.ndarray):
        _header, tensors = _unpack_params_only(layout, prefix)
        lg = forward(tensors, tokens, cfg)  # (B, T, V)
        idx = jnp.clip(pos, 0, tokens.shape[1] - 1)
        rows = jnp.take_along_axis(lg, idx[:, None, None], axis=1)[:, 0, :]
        return rows.reshape(-1)

    return logits


def _unpack_params_only(layout: StateLayout, prefix: jnp.ndarray):
    header = prefix[:HDR]
    tensors = {}
    for n in layout.param_names():
        s = layout.specs[n]
        tensors[n] = prefix[s.offset : s.offset + s.size].reshape(s.shape)
    return header, tensors
