"""AOT lowering: jax programs -> HLO text artifacts for the Rust runtime.

Interchange format is HLO *text* (not serialized HloModuleProto): jax >=
0.5 emits protos with 64-bit instruction ids that the runtime's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Usage (from python/):
    python -m compile.aot --out ../artifacts [--only 'fact-s-.*'] [--list]

Layout written:
    artifacts/<variant>/{init,step[,grad,apply]}.hlo.txt + manifest.json
    artifacts/eval/<eval_key>.hlo.txt + <eval_key>.json
    artifacts/index.json
"""

from __future__ import annotations

import argparse
import json
import os
import re
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .config import VariantCfg, load_variants
from .programs import (
    make_apply,
    make_eval,
    make_grad,
    make_init,
    make_logits,
    make_step,
)
from .state import HDR, StateLayout


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def _write(path: str, text: str) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)


def lower_variant(cfg: VariantCfg, out_dir: str, use_pallas: bool = True) -> dict:
    layout = StateLayout(cfg)
    m = cfg.model
    vdir = os.path.join(out_dir, cfg.name)
    state_spec = jax.ShapeDtypeStruct((layout.total,), jnp.float32)
    tokens_spec = jax.ShapeDtypeStruct((cfg.batch, m.seq_len + 1), jnp.int32)
    entry = {"programs": {}}

    t0 = time.time()
    if "init" in cfg.programs:
        lowered = jax.jit(make_init(layout)).lower(
            jax.ShapeDtypeStruct((), jnp.int32),
            jax.ShapeDtypeStruct((8,), jnp.float32),
        )
        _write(os.path.join(vdir, "init.hlo.txt"), to_hlo_text(lowered))
        entry["programs"]["init"] = f"{cfg.name}/init.hlo.txt"
    if "step" in cfg.programs:
        lowered = jax.jit(make_step(layout, use_pallas)).lower(state_spec, tokens_spec)
        _write(os.path.join(vdir, "step.hlo.txt"), to_hlo_text(lowered))
        entry["programs"]["step"] = f"{cfg.name}/step.hlo.txt"
    if "grad" in cfg.programs:
        lowered = jax.jit(make_grad(layout)).lower(state_spec, tokens_spec)
        _write(os.path.join(vdir, "grad.hlo.txt"), to_hlo_text(lowered))
        entry["programs"]["grad"] = f"{cfg.name}/grad.hlo.txt"
    if "apply" in cfg.programs:
        gspec = jax.ShapeDtypeStruct((1 + layout.n_params,), jnp.float32)
        lowered = jax.jit(make_apply(layout, use_pallas)).lower(state_spec, gspec)
        _write(os.path.join(vdir, "apply.hlo.txt"), to_hlo_text(lowered))
        entry["programs"]["apply"] = f"{cfg.name}/apply.hlo.txt"

    manifest = layout.manifest()
    manifest["programs"] = entry["programs"]
    _write(os.path.join(vdir, "manifest.json"), json.dumps(manifest, indent=1))
    entry["manifest"] = f"{cfg.name}/manifest.json"
    entry["seconds"] = round(time.time() - t0, 2)
    return entry


def lower_eval(cfg: VariantCfg, out_dir: str) -> dict:
    """One eval + logits program per (model, factorize, rank) — shared
    across optimizers. ``logits`` is the serve-time decode step; it rides
    with eval because both consume the header+params prefix only."""
    layout = StateLayout(cfg)
    m = cfg.model
    prefix_spec = jax.ShapeDtypeStruct((layout.params_end,), jnp.float32)
    tokens_spec = jax.ShapeDtypeStruct((cfg.batch, m.seq_len + 1), jnp.int32)
    spans_spec = jax.ShapeDtypeStruct((cfg.batch, 2), jnp.int32)
    lowered = jax.jit(make_eval(layout)).lower(prefix_spec, tokens_spec, spans_spec)
    path = os.path.join(out_dir, "eval", f"{cfg.eval_key}.hlo.txt")
    _write(path, to_hlo_text(lowered))

    gen_tokens_spec = jax.ShapeDtypeStruct((cfg.batch, m.seq_len), jnp.int32)
    pos_spec = jax.ShapeDtypeStruct((cfg.batch,), jnp.int32)
    lowered = jax.jit(make_logits(layout)).lower(prefix_spec, gen_tokens_spec, pos_spec)
    _write(
        os.path.join(out_dir, "eval", f"{cfg.eval_key}.gen.hlo.txt"),
        to_hlo_text(lowered),
    )

    meta = {
        "eval_key": cfg.eval_key,
        "params_end": layout.params_end,
        "batch": cfg.batch,
        "seq_len": m.seq_len,
        "hdr": HDR,
        "out_len": 2 + 2 * cfg.batch,
        "vocab": m.vocab,
        "gen_out_len": cfg.batch * m.vocab,
    }
    _write(
        os.path.join(out_dir, "eval", f"{cfg.eval_key}.json"),
        json.dumps(meta, indent=1),
    )
    return {
        "hlo": f"eval/{cfg.eval_key}.hlo.txt",
        "gen": f"eval/{cfg.eval_key}.gen.hlo.txt",
        "meta": meta,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=os.path.join("..", "artifacts"))
    ap.add_argument("--only", default=None, help="regex filter on variant names")
    ap.add_argument("--list", action="store_true")
    ap.add_argument(
        "--no-pallas",
        action="store_true",
        help="lower optimizer with the jnp reference instead of Pallas kernels",
    )
    args = ap.parse_args()

    variants = load_variants()
    if args.only:
        pat = re.compile(args.only)
        variants = {k: v for k, v in variants.items() if pat.search(k)}
    if args.list:
        for name, v in variants.items():
            layout = StateLayout(v)
            print(
                f"{name:28s} model={v.model.name:7s} opt={v.optimizer:11s} "
                f"params={layout.n_params:>9} state={layout.total:>9}"
            )
        return

    os.makedirs(args.out, exist_ok=True)
    index = {"variants": {}, "evals": {}}
    done_evals: set[str] = set()
    for name, cfg in variants.items():
        print(f"[aot] lowering {name} ...", flush=True)
        entry = lower_variant(cfg, args.out, use_pallas=not args.no_pallas)
        index["variants"][name] = entry
        if "eval" in cfg.programs and cfg.eval_key not in done_evals:
            print(f"[aot]   eval program {cfg.eval_key}", flush=True)
            index["evals"][cfg.eval_key] = lower_eval(cfg, args.out)
            done_evals.add(cfg.eval_key)
        print(f"[aot]   done in {entry['seconds']}s", flush=True)

    with open(os.path.join(args.out, "index.json"), "w") as f:
        json.dump(index, f, indent=1)
    print(f"[aot] wrote {len(index['variants'])} variants, "
          f"{len(index['evals'])} eval programs to {args.out}")


if __name__ == "__main__":
    sys.exit(main())
