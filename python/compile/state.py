"""Flat train-state layout.

Every lowered program exchanges exactly ONE flat f32 vector with the Rust
runtime (the PJRT wrapper in the ``xla`` crate cannot untuple results, see
DESIGN.md). The vector is laid out as::

    state = [ header (HDR=80) | params | optimizer state ]

Header slots carry run-time knobs written by Rust at init (so a single
lowered program serves every lr / token-budget configuration) plus scalar
telemetry and a 64-slot loss ring that lets the trainer read the state back
only every <=64 steps while still recovering a per-step loss curve.

The layout (name -> offset/shape) is serialized into ``manifest.json`` so
the Rust side can view any tensor inside a host copy of the state.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from .config import VariantCfg

# ---- header slots --------------------------------------------------------
STEP = 0  # current step, as f32
TOTAL_STEPS = 1  # run length (knob, written by rust at init)
BASE_LR = 2  # peak lr (knob)
WEIGHT_DECAY = 3  # decoupled wd (knob)
WARMUP_FRAC = 4  # warmup fraction of total steps (knob)
LOSS = 5  # last step loss
LR = 6  # last applied lr
GRAD_NORM = 7  # global grad l2
W_SPEC = 8  # telemetry: ||W||_2 of tracked matrix
DW_SPEC = 9  # telemetry: ||dW||_2 of tracked matrix update
DY_RMS = 10  # telemetry: |dy|_rms for a unit-rms probe
SIGMA_A = 11  # telemetry: power-iter sigma_max(A) of tracked pair
SIGMA_B = 12  # telemetry: power-iter sigma_max(B)
RHO = 13  # telemetry: spectron constraint radius eta/(sA+sB+1)
ALPHA = 14  # self-guided mixing coefficient (0 when unused)
TOKENS_SEEN = 15  # cumulative trained tokens
RING_BASE = 16
RING = 64  # loss ring: ring[step % RING] = loss
HDR = RING_BASE + RING  # = 80

KNOB_SLOTS = 8  # init() takes knobs f32[8] -> header[1..9)? no: [1..5) + pad

MATRIX_NAMES = ("attn_q", "attn_k", "attn_v", "attn_o", "ffn_gate", "ffn_up", "ffn_down")


@dataclass(frozen=True)
class TensorSpec:
    name: str
    shape: tuple[int, ...]
    offset: int  # element offset into the state vector

    @property
    def size(self) -> int:
        return int(np.prod(self.shape))


def matrix_dims(cfg: VariantCfg, mat: str) -> tuple[int, int]:
    """(out_dim m, in_dim n) of each per-layer matrix, y = W x convention."""
    d, f = cfg.model.hidden, cfg.model.ffn
    return {
        "attn_q": (d, d),
        "attn_k": (d, d),
        "attn_v": (d, d),
        "attn_o": (d, d),
        "ffn_gate": (f, d),
        "ffn_up": (f, d),
        "ffn_down": (d, f),
    }[mat]


def is_factorized(cfg: VariantCfg, mat: str) -> bool:
    if cfg.factorize == "none":
        return False
    if cfg.factorize == "ffn":
        return mat.startswith("ffn")
    return True  # "all": every non-embedding matrix


class StateLayout:
    """Orders tensors and assigns offsets; mirrored in manifest.json."""

    def __init__(self, cfg: VariantCfg):
        self.cfg = cfg
        self.specs: dict[str, TensorSpec] = {}
        self._cursor = HDR

        # ---- parameter section (identical across optimizers) ----
        m = cfg.model
        self._add("embed", (m.vocab, m.hidden))
        for mat in MATRIX_NAMES:
            om, on = matrix_dims(cfg, mat)
            if is_factorized(cfg, mat):
                r = cfg.rank(on)
                self._add(f"{mat}_a", (m.layers, om, r))
                self._add(f"{mat}_b", (m.layers, on, r))
            else:
                self._add(mat, (m.layers, om, on))
        self._add("rms1", (m.layers, m.hidden))
        self._add("rms2", (m.layers, m.hidden))
        self._add("rms_f", (m.hidden,))
        self._add("head", (m.vocab, m.hidden))
        self.params_end = self._cursor

        # ---- optimizer section ----
        self._build_opt()
        self.total = self._cursor

    # ------------------------------------------------------------------
    def _add(self, name: str, shape: tuple[int, ...]) -> None:
        assert name not in self.specs, name
        spec = TensorSpec(name, tuple(int(s) for s in shape), self._cursor)
        self.specs[name] = spec
        self._cursor += spec.size

    def _build_opt(self) -> None:
        cfg = self.cfg
        opt = cfg.optimizer
        pnames = self.param_names()

        def adamw_for(names):
            for n in names:
                self._add(f"opt.m.{n}", self.specs[n].shape)
                self._add(f"opt.v.{n}", self.specs[n].shape)

        if opt in ("adamw", "selfguided"):
            adamw_for(pnames)
            if opt == "selfguided":
                # dense auxiliary weights for every factorized pair, plus
                # their own AdamW moments (Wei et al. 2024a, Appendix C).
                for base in self.factor_pairs():
                    om, on = matrix_dims(cfg, base)
                    shape = (cfg.model.layers, om, on)
                    self._add(f"sg.{base}", shape)
                    self._add(f"opt.m.sg.{base}", shape)
                    self._add(f"opt.v.sg.{base}", shape)
        elif opt == "sgd":
            for n in pnames:
                self._add(f"opt.mom.{n}", self.specs[n].shape)
        elif opt in ("muon", "spectron", "renorm"):
            mats = self.matrix_param_names()
            for n in mats:
                self._add(f"opt.mom.{n}", self.specs[n].shape)
            if opt in ("spectron", "renorm"):
                # persisted power-iteration left vectors for each factor
                # (u_A in R^m per layer); `renorm` additionally persists
                # vectors for the momentum normalization.
                for n in mats:
                    if n.endswith("_a") or n.endswith("_b"):
                        lyr, mm, _r = self.specs[n].shape
                        self._add(f"opt.u.{n}", (lyr, mm))
                        if opt == "renorm":
                            self._add(f"opt.um.{n}", (lyr, mm))
            adamw_for([n for n in pnames if n not in mats])
        else:
            raise ValueError(f"unknown optimizer {opt}")

    # ------------------------------------------------------------------
    def param_names(self) -> list[str]:
        return [n for n, s in self.specs.items() if s.offset < self.params_end]

    def opt_names(self) -> list[str]:
        return [n for n, s in self.specs.items() if s.offset >= self.params_end]

    def matrix_param_names(self) -> list[str]:
        """Hidden-layer matrices (muon/spectron targets): stacked 3-D params."""
        return [
            n
            for n in self.param_names()
            if len(self.specs[n].shape) == 3 and n not in ("embed", "head")
        ]

    def factor_pairs(self) -> list[str]:
        """Base names of factorized matrices (have `_a` and `_b` entries)."""
        return [m for m in MATRIX_NAMES if f"{m}_a" in self.specs]

    @property
    def n_params(self) -> int:
        return self.params_end - HDR

    # ---- in-graph pack/unpack ----------------------------------------
    def unpack(self, state):
        header = state[:HDR]
        tensors = {
            n: state[s.offset : s.offset + s.size].reshape(s.shape)
            for n, s in self.specs.items()
        }
        return header, tensors

    def pack(self, header, tensors):
        parts = [header]
        for n, s in self.specs.items():
            t = tensors[n]
            assert t.shape == s.shape, (n, t.shape, s.shape)
            parts.append(t.reshape(-1).astype(jnp.float32))
        return jnp.concatenate(parts)

    def manifest(self) -> dict:
        cfg = self.cfg
        return {
            "variant": cfg.name,
            "model": {
                "name": cfg.model.name,
                "hidden": cfg.model.hidden,
                "layers": cfg.model.layers,
                "heads": cfg.model.heads,
                "vocab": cfg.model.vocab,
                "seq_len": cfg.model.seq_len,
                "ffn": cfg.model.ffn,
            },
            "factorize": cfg.factorize,
            "rank_ratio": cfg.rank_ratio,
            "optimizer": cfg.optimizer,
            "batch": cfg.batch,
            "state_len": self.total,
            "hdr": HDR,
            "ring": RING,
            "ring_base": RING_BASE,
            "params_end": self.params_end,
            "n_params": self.n_params,
            "eval_key": cfg.eval_key,
            "tensors": [
                {"name": s.name, "shape": list(s.shape), "offset": s.offset}
                for s in self.specs.values()
            ],
        }
