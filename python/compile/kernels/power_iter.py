"""L1 Pallas kernel: power iteration spectral-norm estimate (Algorithm 3).

Spectron estimates sigma_max(A) and sigma_max(B) every step with a single
power iteration whose left vector u persists in optimizer state (the
PowerSGD trick the paper cites). Cost is 2mn FLOPs per matrix — two
matvecs — so the kernel is bandwidth-bound: one streaming pass of the
factor through VMEM per matvec, vector operands resident.

Grid iterates the stacked layer axis; each program instance handles one
(m, r) factor and its (m,) vector. interpret=True on this image (see
newton_schulz.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import power_iter_ref


def _pi_kernel(w_ref, u_ref, sig_ref, uo_ref, *, iters: int):
    w = w_ref[0].astype(jnp.float32)  # (m, r)
    u = u_ref[0].astype(jnp.float32)  # (m,)
    u = u / (jnp.sqrt(jnp.sum(u * u)) + 1e-20)
    v = jnp.zeros((w.shape[1],), jnp.float32)
    for _ in range(iters):
        v = jnp.dot(w.T, u)
        v = v / (jnp.sqrt(jnp.sum(v * v)) + 1e-20)
        u = jnp.dot(w, v)
        u = u / (jnp.sqrt(jnp.sum(u * u)) + 1e-20)
    sig_ref[0, 0] = jnp.dot(u, jnp.dot(w, v))  # Rayleigh quotient
    uo_ref[0] = u


@functools.partial(jax.jit, static_argnames=("iters", "use_pallas"))
def power_iter(w: jnp.ndarray, u: jnp.ndarray, iters: int = 1, use_pallas: bool = True):
    """sigma_max estimate. (m,r)/(m,) or stacked (L,m,r)/(L,m).

    Returns (sigma, u'): scalars/vectors, stacked when input is stacked.
    """
    if not use_pallas:
        if w.ndim == 3:
            return jax.vmap(lambda wi, ui: power_iter_ref(wi, ui, iters))(w, u)
        return power_iter_ref(w, u, iters)

    squeeze = w.ndim == 2
    ws = w[None] if squeeze else w
    us = u[None] if squeeze else u
    lyr, m, r = ws.shape
    sig, uo = pl.pallas_call(
        functools.partial(_pi_kernel, iters=iters),
        grid=(lyr,),
        in_specs=[
            pl.BlockSpec((1, m, r), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((lyr, 1), jnp.float32),
            jax.ShapeDtypeStruct((lyr, m), jnp.float32),
        ],
        interpret=True,
    )(ws.astype(jnp.float32), us.astype(jnp.float32))
    sig = sig[:, 0]
    if squeeze:
        return sig[0], uo[0]
    return sig, uo
