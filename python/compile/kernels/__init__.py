"""L1: Pallas kernels for the paper's compute hot-spots.

``newton_schulz`` / ``power_iter`` / ``lowrank_matmul`` take a
``use_pallas`` flag (default True in the optimizer path) and are validated
against the pure-jnp oracles in ``ref.py`` by python/tests.
"""

from .lowrank_matmul import lowrank_matmul
from .newton_schulz import newton_schulz
from .power_iter import power_iter
from .ref import (
    NS_COEFFS,
    NS_EPS,
    lowrank_matmul_ref,
    newton_schulz_ref,
    power_iter_ref,
)

__all__ = [
    "NS_COEFFS",
    "NS_EPS",
    "lowrank_matmul",
    "lowrank_matmul_ref",
    "newton_schulz",
    "newton_schulz_ref",
    "power_iter",
    "power_iter_ref",
]
