"""L1 Pallas kernel: fused low-rank apply y = (x @ B) @ Aᵀ.

The inference-efficiency story of the paper rests on replacing a (m, n)
matmul (2tmn FLOPs) by two thin matmuls through the rank bottleneck
(2tr(m+n) FLOPs — a 2x saving at rank ratio 0.25). The fusion matters on
real hardware because the intermediate (t, r) activation never leaves
VMEM: grid tiles the token axis, each program instance streams an x-tile
in, keeps both factors resident (they are small: n*r + m*r elements), and
writes only the final y-tile back to HBM. This is the TPU analogue of the
shared-memory staging a CUDA kernel would do.

interpret=True on this image (see newton_schulz.py). The L2 model can opt
into this kernel via ``use_pallas_matmul``; it is numerically identical to
the XLA-fused ``(x @ B) @ A.T`` (validated in python/tests).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lr_kernel(x_ref, a_ref, b_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)  # (bt, n) token tile
    a = a_ref[...].astype(jnp.float32)  # (m, r) resident factor
    b = b_ref[...].astype(jnp.float32)  # (n, r) resident factor
    h = jnp.dot(x, b)  # (bt, r) stays in VMEM
    o_ref[...] = jnp.dot(h, a.T)


@functools.partial(jax.jit, static_argnames=("block_t",))
def lowrank_matmul(x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray, block_t: int = 128):
    """y = (x @ B) @ Aᵀ. x: (t, n); a: (m, r); b: (n, r) -> (t, m)."""
    t, n = x.shape
    m, r = a.shape
    assert b.shape == (n, r), (b.shape, (n, r))
    bt = min(block_t, t)
    assert t % bt == 0, f"token dim {t} not divisible by block {bt}"
    return pl.pallas_call(
        _lr_kernel,
        grid=(t // bt,),
        in_specs=[
            pl.BlockSpec((bt, n), lambda i: (i, 0)),
            pl.BlockSpec((m, r), lambda i: (0, 0)),
            pl.BlockSpec((n, r), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, m), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), a, b)
