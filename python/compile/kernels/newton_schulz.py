"""L1 Pallas kernel: Newton-Schulz orthogonalization (paper Algorithm 2).

This is the paper's compute hot-spot: Spectron orthogonalizes the momentum
of every factor matrix each step (6*k_ns*n*m^2 FLOPs, the <1% overhead
claim of Section 5). The kernel is written for the TPU memory hierarchy:

* One (m, r) factor momentum fits comfortably in VMEM (largest factor in
  this repo's model family is (704, 176) -> ~0.5 MB in f32; the paper-scale
  (4096, 1024) is 16 MB, at which point the grid below tiles the stacked
  layer axis so each program instance still holds a single factor).
* All 5 NS iterations run inside one kernel invocation: the Gram matrix
  G = XᵀX (r x r) and the polynomial update are MXU matmuls chained in
  VMEM with **no HBM round-trips between iterations** — the GPU paper's
  "keep the iterate resident" insight mapped to the TPU scratchpad.
* The grid iterates over the stacked layer axis (params are stored
  [layers, m, r]), giving pipelined HBM->VMEM loads across layers
  (BlockSpec double-buffering).

On this image Pallas must run ``interpret=True`` (real TPU lowering emits
Mosaic custom-calls the CPU PJRT plugin cannot execute); numerics are
validated against ``ref.newton_schulz_ref`` in python/tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NS_COEFFS, NS_EPS, newton_schulz_ref


def _ns_kernel(x_ref, o_ref, *, steps: int):
    """Kernel body: orthogonalize one (m, r) block, m >= r."""
    a, b, c = NS_COEFFS
    x = x_ref[0].astype(jnp.float32)  # (m, r) — block carries a unit layer dim
    x = x / (jnp.sqrt(jnp.sum(x * x)) + NS_EPS)
    for _ in range(steps):
        gram = jnp.dot(x.T, x)  # (r, r) on the MXU, stays in VMEM
        bmat = b * gram + c * jnp.dot(gram, gram)
        x = a * x + jnp.dot(x, bmat)
    o_ref[0] = x


@functools.partial(jax.jit, static_argnames=("steps", "use_pallas"))
def newton_schulz(g: jnp.ndarray, steps: int = 5, use_pallas: bool = True):
    """Orthogonalize ``g``.

    Accepts (m, r) or a stacked (layers, m, r); tall orientation (m >= r)
    is required for the Pallas path (factor matrices always satisfy this),
    anything else falls back to the jnp reference.
    """
    if not use_pallas:
        if g.ndim == 3:
            return jax.vmap(lambda t: newton_schulz_ref(t, steps))(g)
        return newton_schulz_ref(g, steps)

    squeeze = g.ndim == 2
    x = g[None] if squeeze else g
    lyr, m, r = x.shape
    if m < r:  # wide matrices: reference path handles the transpose dance
        out = jax.vmap(lambda t: newton_schulz_ref(t, steps))(x)
        return out[0] if squeeze else out

    out = pl.pallas_call(
        functools.partial(_ns_kernel, steps=steps),
        grid=(lyr,),
        in_specs=[pl.BlockSpec((1, m, r), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, m, r), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((lyr, m, r), jnp.float32),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(x.astype(jnp.float32))
    out = out.astype(g.dtype)
    return out[0] if squeeze else out
