"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

Every L1 kernel is validated against these references by
``python/tests/test_kernels.py`` (hypothesis sweeps shapes/dtypes) before
anything is lowered to HLO.
"""

from __future__ import annotations

import jax.numpy as jnp

# Newton-Schulz quintic coefficients from Jordan et al. (2024), used by the
# paper's Algorithm 2.
NS_COEFFS = (3.4445, -4.7750, 2.0315)
NS_EPS = 1e-7


def newton_schulz_ref(g: jnp.ndarray, steps: int = 5) -> jnp.ndarray:
    """Orthogonalize ``g`` (singular values -> ~1) via Newton-Schulz.

    Matches the paper's Algorithm 2. ``g`` is (m, n); the Gram matrix is
    always formed on the smaller side, which for tall factor matrices
    (m >> r) keeps the iteration at r x r.
    """
    a, b, c = NS_COEFFS
    x = g.astype(jnp.float32)
    transposed = x.shape[0] < x.shape[1]
    if transposed:
        x = x.T  # make tall: gram on the trailing (small) dim
    x = x / (jnp.linalg.norm(x) + NS_EPS)
    for _ in range(steps):
        gram = x.T @ x  # (n, n), n = small side
        bmat = b * gram + c * (gram @ gram)
        x = a * x + x @ bmat
    return (x.T if transposed else x).astype(g.dtype)


def power_iter_ref(w: jnp.ndarray, u: jnp.ndarray, iters: int = 1):
    """Paper Algorithm 3: approximate sigma_max and left singular vector.

    Returns (sigma, u'). ``w`` is (p, q), ``u`` is (p,).
    """
    w = w.astype(jnp.float32)
    u = u.astype(jnp.float32)
    u = u / (jnp.linalg.norm(u) + 1e-20)
    v = None
    for _ in range(iters):
        v = w.T @ u
        v = v / (jnp.linalg.norm(v) + 1e-20)
        u = w @ v
        u = u / (jnp.linalg.norm(u) + 1e-20)
    sigma = u @ (w @ v)
    return sigma, u


def lowrank_matmul_ref(x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray):
    """Fused low-rank apply: y = (x @ B) @ Aᵀ for W = A Bᵀ (y = W x).

    ``x`` is (t, n), ``a`` is (m, r), ``b`` is (n, r); result (t, m).
    """
    return (x @ b) @ a.T
