"""Config registry shared with the Rust runtime.

Both sides read the same ``configs/*.toml`` files; python lowers programs
from them at build time, rust resolves the identical variant names at run
time. Keep this module dependency-free (stdlib ``tomllib`` only).
"""

from __future__ import annotations

import math
import os
import tomllib
from dataclasses import dataclass, field

_REPO = os.path.normpath(os.path.join(os.path.dirname(__file__), "..", ".."))


def _round_mult(x: float, m: int) -> int:
    return max(m, int(round(x / m)) * m)


@dataclass(frozen=True)
class ModelCfg:
    """LLaMA-style architecture shape (see configs/models.toml)."""

    name: str
    hidden: int
    layers: int
    heads: int
    vocab: int
    seq_len: int

    @property
    def head_dim(self) -> int:
        assert self.hidden % self.heads == 0
        return self.hidden // self.heads

    @property
    def ffn(self) -> int:
        """SwiGLU inner width: 8/3 * hidden rounded to a multiple of 32."""
        return _round_mult(8.0 / 3.0 * self.hidden, 32)


@dataclass(frozen=True)
class VariantCfg:
    """One AOT program family (configs/variants.toml)."""

    name: str
    model: ModelCfg
    factorize: str  # "all" | "ffn" | "none"
    rank_ratio: float
    optimizer: str  # adamw | sgd | muon | renorm | spectron | selfguided
    batch: int
    telemetry: bool
    telemetry_matrix: str
    emb_lr_mult: float
    programs: tuple[str, ...] = field(default=("init", "step", "eval"))

    def rank(self, fan_in: int) -> int:
        """Low rank for a matrix with input dimension ``fan_in``.

        The paper sets r = rank_ratio * n (n = input dim); we additionally
        round to a multiple of 8 for kernel tile friendliness.
        """
        return _round_mult(self.rank_ratio * fan_in, 8)

    @property
    def eval_key(self) -> str:
        """Variants sharing (model, factorize, rank) share one eval.hlo."""
        if self.factorize == "none":
            return f"eval-{self.model.name}-dense"
        return f"eval-{self.model.name}-{self.factorize}-r{self.rank_ratio:g}"


def load_models(path: str | None = None) -> dict[str, ModelCfg]:
    path = path or os.path.join(_REPO, "configs", "models.toml")
    with open(path, "rb") as f:
        raw = tomllib.load(f)
    out = {}
    for name, m in raw["model"].items():
        out[name] = ModelCfg(
            name=name,
            hidden=int(m["hidden"]),
            layers=int(m["layers"]),
            heads=int(m["heads"]),
            vocab=int(m["vocab"]),
            seq_len=int(m["seq_len"]),
        )
    return out


def load_variants(path: str | None = None) -> dict[str, VariantCfg]:
    models = load_models()
    path = path or os.path.join(_REPO, "configs", "variants.toml")
    with open(path, "rb") as f:
        raw = tomllib.load(f)
    d = raw.get("defaults", {})
    out = {}
    for name, v in raw["variant"].items():
        out[name] = VariantCfg(
            name=name,
            model=models[v["model"]],
            factorize=str(v.get("factorize", "all")),
            rank_ratio=float(v.get("rank_ratio", d.get("rank_ratio", 0.25))),
            optimizer=str(v["optimizer"]),
            batch=int(v.get("batch", d.get("batch", 8))),
            telemetry=bool(v.get("telemetry", d.get("telemetry", True))),
            telemetry_matrix=str(
                v.get("telemetry_matrix", d.get("telemetry_matrix", "attn_o"))
            ),
            emb_lr_mult=float(v.get("emb_lr_mult", d.get("emb_lr_mult", 0.3))),
            programs=tuple(v.get("programs", ["init", "step", "eval"])),
        )
    return out
