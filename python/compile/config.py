"""Config registry shared with the Rust runtime.

Both sides read the same ``configs/*.toml`` files; python lowers programs
from them at build time, rust resolves the identical variant names at run
time. Keep this module dependency-free (stdlib ``tomllib`` only).
"""

from __future__ import annotations

import math
import os
from dataclasses import dataclass, field

try:  # stdlib from 3.11; this testbed pins 3.10, so gate it (DESIGN.md)
    import tomllib
except ModuleNotFoundError:  # pragma: no cover - depends on interpreter
    tomllib = None

_REPO = os.path.normpath(os.path.join(os.path.dirname(__file__), "..", ".."))


def _round_mult(x: float, m: int) -> int:
    return max(m, int(round(x / m)) * m)


@dataclass(frozen=True)
class ModelCfg:
    """LLaMA-style architecture shape (see configs/models.toml)."""

    name: str
    hidden: int
    layers: int
    heads: int
    vocab: int
    seq_len: int

    @property
    def head_dim(self) -> int:
        assert self.hidden % self.heads == 0
        return self.hidden // self.heads

    @property
    def ffn(self) -> int:
        """SwiGLU inner width: 8/3 * hidden rounded to a multiple of 32."""
        return _round_mult(8.0 / 3.0 * self.hidden, 32)


@dataclass(frozen=True)
class VariantCfg:
    """One AOT program family (configs/variants.toml)."""

    name: str
    model: ModelCfg
    factorize: str  # "all" | "ffn" | "none"
    rank_ratio: float
    optimizer: str  # adamw | sgd | muon | renorm | spectron | selfguided
    batch: int
    telemetry: bool
    telemetry_matrix: str
    emb_lr_mult: float
    programs: tuple[str, ...] = field(default=("init", "step", "eval"))

    def rank(self, fan_in: int) -> int:
        """Low rank for a matrix with input dimension ``fan_in``.

        The paper sets r = rank_ratio * n (n = input dim); we additionally
        round to a multiple of 8 for kernel tile friendliness.
        """
        return _round_mult(self.rank_ratio * fan_in, 8)

    @property
    def eval_key(self) -> str:
        """Variants sharing (model, factorize, rank) share one eval.hlo."""
        if self.factorize == "none":
            return f"eval-{self.model.name}-dense"
        return f"eval-{self.model.name}-{self.factorize}-r{self.rank_ratio:g}"


def _parse_toml_value(text: str):
    text = text.strip()
    if text.startswith('"') and text.endswith('"') and len(text) >= 2:
        return text[1:-1]
    if text.startswith("[") and text.endswith("]"):
        inner = text[1:-1].strip()
        return [_parse_toml_value(p) for p in inner.split(",")] if inner else []
    if text in ("true", "false"):
        return text == "true"
    try:
        return int(text)
    except ValueError:
        return float(text)


def _toml_load(path: str) -> dict:
    """Read a config file. Prefers stdlib ``tomllib``; on 3.10 falls back
    to the same TOML subset ``rust/src/util/toml.rs`` accepts ([a.b]
    headers, scalar/flat-array values, # comments)."""
    if tomllib is not None:
        with open(path, "rb") as f:
            return tomllib.load(f)
    raw: dict = {}
    with open(path, "r") as f:
        table = raw
        for lineno, line in enumerate(f, 1):
            line = line.split("#", 1)[0].strip()
            if not line:
                continue
            if line.startswith("["):
                if not line.endswith("]"):
                    raise ValueError(f"{path}:{lineno}: unterminated table header")
                table = raw
                for part in line[1:-1].strip().split("."):
                    table = table.setdefault(part.strip(), {})
                continue
            key, eq, val = line.partition("=")
            if not eq:
                raise ValueError(f"{path}:{lineno}: expected key = value")
            table[key.strip()] = _parse_toml_value(val)
    return raw


def load_models(path: str | None = None) -> dict[str, ModelCfg]:
    path = path or os.path.join(_REPO, "configs", "models.toml")
    raw = _toml_load(path)
    out = {}
    for name, m in raw["model"].items():
        out[name] = ModelCfg(
            name=name,
            hidden=int(m["hidden"]),
            layers=int(m["layers"]),
            heads=int(m["heads"]),
            vocab=int(m["vocab"]),
            seq_len=int(m["seq_len"]),
        )
    return out


def load_variants(path: str | None = None) -> dict[str, VariantCfg]:
    models = load_models()
    path = path or os.path.join(_REPO, "configs", "variants.toml")
    raw = _toml_load(path)
    d = raw.get("defaults", {})
    out = {}
    for name, v in raw["variant"].items():
        out[name] = VariantCfg(
            name=name,
            model=models[v["model"]],
            factorize=str(v.get("factorize", "all")),
            rank_ratio=float(v.get("rank_ratio", d.get("rank_ratio", 0.25))),
            optimizer=str(v["optimizer"]),
            batch=int(v.get("batch", d.get("batch", 8))),
            telemetry=bool(v.get("telemetry", d.get("telemetry", True))),
            telemetry_matrix=str(
                v.get("telemetry_matrix", d.get("telemetry_matrix", "attn_o"))
            ),
            emb_lr_mult=float(v.get("emb_lr_mult", d.get("emb_lr_mult", 0.3))),
            programs=tuple(v.get("programs", ["init", "step", "eval"])),
        )
    return out
