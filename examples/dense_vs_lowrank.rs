//! Dense vs natively-low-rank training at equal FLOPs (the paper's
//! Figure 1/5 story, runnable standalone on the S-scale models for speed).
//!
//!     cargo run --release --example dense_vs_lowrank
//!
//! Trains dense-s (Muon) and fact-s (Spectron) for FLOP-matched step
//! budgets and prints both loss curves against training FLOPs plus the
//! final perplexities and the parameter savings.

use std::sync::Arc;

use anyhow::Result;
use spectron::config::RunCfg;
use spectron::data::dataset::Split;
use spectron::exp::{matched_flop_steps, plot, Ctx};
use spectron::runtime::Runtime;
use spectron::train::Trainer;

fn main() -> Result<()> {
    let dense = "dense-s-muon";
    let fact = "fact-s-spectron";
    let dense_steps: usize = std::env::var("DVL_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(150);

    let ctx = Arc::new(Ctx::new(4000, false)?);
    let rt = Runtime::shared()?;
    let fact_steps = matched_flop_steps(&ctx, dense, fact, dense_steps)?;
    let dn = ctx.idx.manifest(dense)?.n_params as f64;
    let fnp = ctx.idx.manifest(fact)?.n_params as f64;
    println!(
        "dense {dense}: {:.2}M params, {dense_steps} steps\nfact  {fact}: {:.2}M params ({:.0}% fewer), {fact_steps} steps (FLOP-matched)\n",
        dn / 1e6,
        fnp / 1e6,
        (1.0 - fnp / dn) * 100.0
    );

    let mut series = Vec::new();
    let mut finals = Vec::new();
    for (v_name, steps, lr) in [(dense, dense_steps, 0.01), (fact, fact_steps, 0.01)] {
        let v = ctx.reg.variant(v_name).map_err(anyhow::Error::msg)?;
        let run = RunCfg {
            total_steps: steps,
            base_lr: lr,
            weight_decay: 0.01,
            warmup_frac: 0.05,
            seed: 3,
            read_interval: 25,
        };
        let mut trainer = Trainer::new(&rt, &ctx.idx, v, run.clone())?;
        let mut batches = ctx.ds.batches(Split::Train, v.batch, run.seed);
        println!("training {v_name} ({steps} steps) ...");
        let res = trainer.train(&mut batches, steps)?;
        let state = trainer.state_vec()?;
        let ppl = ctx.ppl(&rt, v_name, &state)?;
        let flops_per_step = 6.0 * ctx.idx.manifest(v_name)?.n_params as f64 * 1024.0;
        series.push(plot::Series::new(
            v_name,
            res.losses
                .iter()
                .map(|&(s, l)| (s as f64 * flops_per_step, l as f64))
                .collect(),
        ));
        finals.push((v_name, res.final_loss, ppl));
    }

    println!(
        "{}",
        plot::render("dense vs low-rank at equal FLOPs", "train FLOPs", "loss", &series)
    );
    for (name, loss, ppl) in finals {
        println!("{name:<18} final loss {loss:.4}   val ppl {ppl:.2}");
    }
    println!("\nexpected shape (paper Fig 1/5): both curves end at a similar loss —");
    println!("the factorized model matches dense quality with ~40% fewer parameters.");
    Ok(())
}
