//! serve_bench — open-loop load generator for `repro serve`
//! (DESIGN.md §Serving, docs/adr/006).
//!
//! Closed-loop clients hide queueing delay: a slow server slows the
//! arrival process down with it. This harness instead fires generate
//! requests at fixed arrival rates — each request on its own connection,
//! dispatched on schedule regardless of how the previous one is doing —
//! against the native engine in two configurations:
//!
//!   cache=on   continuous batching: KV-cache decode slots
//!              (`--slots DECODE_SLOTS_DEFAULT`), requests join and leave
//!              the decode loop per step
//!   cache=off  lockstep baseline (`--slots 0`): full-forward generate
//!              batches, a short request waits for the whole batch
//!
//! Client-side p50/p95/p99 per (rate, mode) is printed and recorded, and
//! the run ends with [`bench::write_json`], so
//! `make serve-bench` lands `BENCH_serve_latency.json`. The acceptance
//! signal is the p99 gap between the two modes at equal arrival rates.
//!
//!     cargo run --release --example serve_bench        (BENCH_FAST=1 to smoke)
//!
//! With `ROUTE_BENCH=1` (DESIGN.md §Routing, `make route-bench`) the
//! harness instead drives open-loop *score* traffic through the replica
//! router over mock replicas — 1 replica, 2 replicas, and 2 replicas
//! with a mid-run outage injected by the chaos proxy — and lands
//! `BENCH_route_latency.json`. Scores are the idempotent op: the outage
//! row's acceptance signal is that every request still succeeds and the
//! failover cost shows up only in the latency tail.
//!
//! Env knobs: SERVE_BENCH_RATES (req/s list, "20,50"), SERVE_BENCH_REQS
//! per rate (40; 12 under BENCH_FAST), SERVE_BENCH_MAX_TOKENS (8).

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};
use spectron::config::{Registry, RunCfg};
use spectron::data::bpe::Bpe;
use spectron::data::corpus::Corpus;
use spectron::serve::{
    BatchEngine, ChaosPlan, ChaosProxy, EngineFactory, MockEngine, NativeEngine,
    RouteCfg, Router, ServeCfg, Server, ServerHandle, DECODE_SLOTS_DEFAULT,
};
use spectron::train::{checkpoint, Trainer};
use spectron::util::bench::{self, header, BenchResult};
use spectron::util::json::Json;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// In-process native server over a fresh z0 init checkpoint. `slots > 0`
/// enables continuous batching; `slots == 0` is the lockstep baseline.
fn spawn_native(slots: usize) -> Result<(ServerHandle, std::path::PathBuf)> {
    let reg = Registry::load().map_err(|e| anyhow!(e))?;
    let variant = "fact-z0-spectron";
    let v = reg.variant(variant).map_err(|e| anyhow!(e))?;
    let mut trainer = Trainer::native(v, RunCfg::default())?;
    let ckpt = std::env::temp_dir().join(format!(
        "spectron-serve-bench-{slots}-{}.ckpt",
        std::process::id()
    ));
    checkpoint::save(&ckpt, variant, &trainer.state_vec()?)?;

    let corpus = Corpus::new(Default::default());
    let bpe = Arc::new(Bpe::train(&corpus.text_range(1, 60), v.model.vocab));
    let mut ckpts = BTreeMap::new();
    ckpts.insert(variant.to_string(), ckpt.clone());
    let factory: EngineFactory = Arc::new(move || {
        Ok(Box::new(NativeEngine::with_opts(
            bpe.clone(),
            ckpts.clone(),
            2,
            1,
            slots,
        )?) as Box<dyn BatchEngine>)
    });
    let cfg = ServeCfg {
        addr: "127.0.0.1:0".into(),
        max_batch: 4,
        max_wait: Duration::from_millis(5),
        workers: 1,
        default_variant: Some(variant.to_string()),
        metrics_name: None,
        idle_timeout: None,
        queue_cap: 1024,
    };
    Ok((Server::spawn(cfg, factory)?, ckpt))
}

/// One open-loop arrival: its own connection, one generate, one reply.
/// Returns end-to-end latency in seconds (connect included — that is what
/// a client sees).
fn one_request(addr: SocketAddr, id: usize, max_tokens: usize) -> Result<f64> {
    let t0 = Instant::now();
    let stream = TcpStream::connect(addr).context("connect")?;
    stream.set_read_timeout(Some(Duration::from_secs(60)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    writeln!(
        writer,
        r#"{{"id":{id},"op":"generate","prompt":"the cat sat on request {id}","max_tokens":{max_tokens},"temperature":0.9,"seed":{id}}}"#
    )?;
    writer.flush()?;
    let mut line = String::new();
    anyhow::ensure!(reader.read_line(&mut line)? > 0, "server closed");
    let j = Json::parse(line.trim()).map_err(|e| anyhow!(e))?;
    anyhow::ensure!(
        j.get("ok") == Some(&Json::Bool(true)),
        "request failed: {line}"
    );
    Ok(t0.elapsed().as_secs_f64())
}

/// Fire `reqs` requests at `rate` arrivals/second and join them all.
fn run_phase(
    addr: SocketAddr,
    rate: f64,
    reqs: usize,
    max_tokens: usize,
) -> Result<Vec<f64>> {
    let interval = Duration::from_secs_f64(1.0 / rate.max(1e-9));
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(reqs);
        for i in 0..reqs {
            handles.push(scope.spawn(move || one_request(addr, i, max_tokens)));
            std::thread::sleep(interval);
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("client thread"))
            .collect()
    })
}

/// A mock replica for the routed rows: routing overhead and failover
/// cost are the signal, so the engine is a constant 2 ms stand-in.
fn spawn_mock() -> Result<ServerHandle> {
    let cfg = ServeCfg {
        addr: "127.0.0.1:0".into(),
        max_batch: 8,
        max_wait: Duration::from_millis(5),
        workers: 1,
        default_variant: Some("mock".into()),
        metrics_name: None,
        idle_timeout: None,
        queue_cap: 1024,
    };
    Server::spawn(
        cfg,
        MockEngine::factory(
            Duration::from_millis(2),
            Arc::new(std::sync::Mutex::new(Vec::new())),
        ),
    )
}

fn bench_route_cfg() -> RouteCfg {
    RouteCfg {
        addr: "127.0.0.1:0".into(),
        retries: 8,
        retry_base: Duration::from_millis(20),
        retry_cap: Duration::from_millis(100),
        health_interval: Duration::from_millis(50),
        ..RouteCfg::default()
    }
}

/// One open-loop score through the router: own connection, must succeed
/// even mid-outage (failover is the router's job, not the client's).
fn one_score(addr: SocketAddr, id: usize) -> Result<f64> {
    let t0 = Instant::now();
    let stream = TcpStream::connect(addr).context("connect")?;
    stream.set_read_timeout(Some(Duration::from_secs(60)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    writeln!(writer, r#"{{"id":{id},"op":"score","text":"the cat sat on request {id}"}}"#)?;
    writer.flush()?;
    let mut line = String::new();
    anyhow::ensure!(reader.read_line(&mut line)? > 0, "router closed");
    let j = Json::parse(line.trim()).map_err(|e| anyhow!(e))?;
    anyhow::ensure!(
        j.get("ok") == Some(&Json::Bool(true)),
        "routed score failed: {line}"
    );
    Ok(t0.elapsed().as_secs_f64())
}

fn run_score_phase(addr: SocketAddr, rate: f64, reqs: usize) -> Result<Vec<f64>> {
    let interval = Duration::from_secs_f64(1.0 / rate.max(1e-9));
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(reqs);
        for i in 0..reqs {
            handles.push(scope.spawn(move || one_score(addr, i)));
            std::thread::sleep(interval);
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("client thread"))
            .collect()
    })
}

fn route_bench(reqs: usize, rates: &[f64]) -> Result<()> {
    println!(
        "== route_bench: open-loop routed scores, {reqs} reqs per rate, \
         rates {rates:?}/s =="
    );
    header("route: open-loop score latency through the replica router");

    // routing overhead: 1 replica vs 2 (default-variant traffic spreads)
    for replicas in [1usize, 2] {
        let servers = (0..replicas).map(|_| spawn_mock()).collect::<Result<Vec<_>>>()?;
        let addrs = servers.iter().map(|s| s.addr.to_string()).collect();
        let handle = Router::spawn(bench_route_cfg(), addrs, None)?;
        for &rate in rates {
            let lats = run_score_phase(handle.addr, rate, reqs)?;
            bench::record(BenchResult::from_samples(
                &format!("routed replicas={replicas} rate={rate:.0}/s"),
                &lats,
            ));
        }
        handle.shutdown();
        for s in servers {
            s.shutdown();
        }
    }

    // failover row: replica 0 sits behind the chaos proxy, which blinks
    // the link down for 250 ms a third of the way into each phase
    let (s0, s1) = (spawn_mock()?, spawn_mock()?);
    let plan = ChaosPlan::new();
    let proxy = ChaosProxy::spawn(&s0.addr.to_string(), plan.clone())
        .context("chaos proxy")?;
    let handle = Router::spawn(
        bench_route_cfg(),
        vec![proxy.addr.to_string(), s1.addr.to_string()],
        None,
    )?;
    for &rate in rates {
        let phase_secs = reqs as f64 / rate.max(1e-9);
        let blink = {
            let plan = plan.clone();
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_secs_f64(phase_secs / 3.0));
                plan.set_down(true);
                std::thread::sleep(Duration::from_millis(250));
                plan.set_down(false);
            })
        };
        let lats = run_score_phase(handle.addr, rate, reqs)?;
        blink.join().expect("blink thread");
        bench::record(BenchResult::from_samples(
            &format!("routed replicas=2 mid-run-outage rate={rate:.0}/s"),
            &lats,
        ));
    }
    handle.shutdown();
    proxy.stop();
    s0.shutdown();
    s1.shutdown();

    bench::write_json("route_latency");
    Ok(())
}

fn main() -> Result<()> {
    let fast = std::env::var("BENCH_FAST").is_ok();
    let reqs = env_usize("SERVE_BENCH_REQS", if fast { 12 } else { 40 });
    let max_tokens = env_usize("SERVE_BENCH_MAX_TOKENS", 8);
    let rates: Vec<f64> = std::env::var("SERVE_BENCH_RATES")
        .unwrap_or_else(|_| "20,50".into())
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    anyhow::ensure!(!rates.is_empty(), "SERVE_BENCH_RATES parsed to nothing");

    if std::env::var("ROUTE_BENCH").is_ok() {
        return route_bench(reqs, &rates);
    }

    println!(
        "== serve_bench: open-loop, {reqs} generate reqs per rate, \
         rates {rates:?}/s, max_tokens {max_tokens} =="
    );
    header("serve: open-loop generate latency (native engine)");
    for (slots, label) in [(DECODE_SLOTS_DEFAULT, "on"), (0usize, "off")] {
        let (handle, ckpt) = spawn_native(slots)?;
        for &rate in &rates {
            let lats = run_phase(handle.addr, rate, reqs, max_tokens)?;
            bench::record(BenchResult::from_samples(
                &format!("open-loop rate={rate:.0}/s cache={label}"),
                &lats,
            ));
        }
        handle.shutdown();
        std::fs::remove_file(&ckpt).ok();
    }

    bench::write_json("serve_latency");
    Ok(())
}
