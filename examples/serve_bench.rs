//! serve_bench — open-loop load generator for `repro serve`
//! (DESIGN.md §Serving, docs/adr/006).
//!
//! Closed-loop clients hide queueing delay: a slow server slows the
//! arrival process down with it. This harness instead fires generate
//! requests at fixed arrival rates — each request on its own connection,
//! dispatched on schedule regardless of how the previous one is doing —
//! against the native engine in two configurations:
//!
//!   cache=on   continuous batching: KV-cache decode slots
//!              (`--slots DECODE_SLOTS_DEFAULT`), requests join and leave
//!              the decode loop per step
//!   cache=off  lockstep baseline (`--slots 0`): full-forward generate
//!              batches, a short request waits for the whole batch
//!
//! Client-side p50/p95/p99 per (rate, mode) is printed and recorded, and
//! the run ends with [`bench::write_json`], so
//! `make serve-bench` lands `BENCH_serve_latency.json`. The acceptance
//! signal is the p99 gap between the two modes at equal arrival rates.
//!
//!     cargo run --release --example serve_bench        (BENCH_FAST=1 to smoke)
//!
//! Env knobs: SERVE_BENCH_RATES (req/s list, "20,50"), SERVE_BENCH_REQS
//! per rate (40; 12 under BENCH_FAST), SERVE_BENCH_MAX_TOKENS (8).

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};
use spectron::config::{Registry, RunCfg};
use spectron::data::bpe::Bpe;
use spectron::data::corpus::Corpus;
use spectron::serve::{
    BatchEngine, EngineFactory, NativeEngine, ServeCfg, Server, ServerHandle,
    DECODE_SLOTS_DEFAULT,
};
use spectron::train::{checkpoint, Trainer};
use spectron::util::bench::{self, header, BenchResult};
use spectron::util::json::Json;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// In-process native server over a fresh z0 init checkpoint. `slots > 0`
/// enables continuous batching; `slots == 0` is the lockstep baseline.
fn spawn_native(slots: usize) -> Result<(ServerHandle, std::path::PathBuf)> {
    let reg = Registry::load().map_err(|e| anyhow!(e))?;
    let variant = "fact-z0-spectron";
    let v = reg.variant(variant).map_err(|e| anyhow!(e))?;
    let mut trainer = Trainer::native(v, RunCfg::default())?;
    let ckpt = std::env::temp_dir().join(format!(
        "spectron-serve-bench-{slots}-{}.ckpt",
        std::process::id()
    ));
    checkpoint::save(&ckpt, variant, &trainer.state_vec()?)?;

    let corpus = Corpus::new(Default::default());
    let bpe = Arc::new(Bpe::train(&corpus.text_range(1, 60), v.model.vocab));
    let mut ckpts = BTreeMap::new();
    ckpts.insert(variant.to_string(), ckpt.clone());
    let factory: EngineFactory = Arc::new(move || {
        Ok(Box::new(NativeEngine::with_opts(
            bpe.clone(),
            ckpts.clone(),
            2,
            1,
            slots,
        )?) as Box<dyn BatchEngine>)
    });
    let cfg = ServeCfg {
        addr: "127.0.0.1:0".into(),
        max_batch: 4,
        max_wait: Duration::from_millis(5),
        workers: 1,
        default_variant: Some(variant.to_string()),
        metrics_name: None,
        queue_cap: 1024,
    };
    Ok((Server::spawn(cfg, factory)?, ckpt))
}

/// One open-loop arrival: its own connection, one generate, one reply.
/// Returns end-to-end latency in seconds (connect included — that is what
/// a client sees).
fn one_request(addr: SocketAddr, id: usize, max_tokens: usize) -> Result<f64> {
    let t0 = Instant::now();
    let stream = TcpStream::connect(addr).context("connect")?;
    stream.set_read_timeout(Some(Duration::from_secs(60)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    writeln!(
        writer,
        r#"{{"id":{id},"op":"generate","prompt":"the cat sat on request {id}","max_tokens":{max_tokens},"temperature":0.9,"seed":{id}}}"#
    )?;
    writer.flush()?;
    let mut line = String::new();
    anyhow::ensure!(reader.read_line(&mut line)? > 0, "server closed");
    let j = Json::parse(line.trim()).map_err(|e| anyhow!(e))?;
    anyhow::ensure!(
        j.get("ok") == Some(&Json::Bool(true)),
        "request failed: {line}"
    );
    Ok(t0.elapsed().as_secs_f64())
}

/// Fire `reqs` requests at `rate` arrivals/second and join them all.
fn run_phase(
    addr: SocketAddr,
    rate: f64,
    reqs: usize,
    max_tokens: usize,
) -> Result<Vec<f64>> {
    let interval = Duration::from_secs_f64(1.0 / rate.max(1e-9));
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(reqs);
        for i in 0..reqs {
            handles.push(scope.spawn(move || one_request(addr, i, max_tokens)));
            std::thread::sleep(interval);
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("client thread"))
            .collect()
    })
}

fn main() -> Result<()> {
    let fast = std::env::var("BENCH_FAST").is_ok();
    let reqs = env_usize("SERVE_BENCH_REQS", if fast { 12 } else { 40 });
    let max_tokens = env_usize("SERVE_BENCH_MAX_TOKENS", 8);
    let rates: Vec<f64> = std::env::var("SERVE_BENCH_RATES")
        .unwrap_or_else(|_| "20,50".into())
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    anyhow::ensure!(!rates.is_empty(), "SERVE_BENCH_RATES parsed to nothing");

    println!(
        "== serve_bench: open-loop, {reqs} generate reqs per rate, \
         rates {rates:?}/s, max_tokens {max_tokens} =="
    );
    header("serve: open-loop generate latency (native engine)");
    for (slots, label) in [(DECODE_SLOTS_DEFAULT, "on"), (0usize, "off")] {
        let (handle, ckpt) = spawn_native(slots)?;
        for &rate in &rates {
            let lats = run_phase(handle.addr, rate, reqs, max_tokens)?;
            bench::record(BenchResult::from_samples(
                &format!("open-loop rate={rate:.0}/s cache={label}"),
                &lats,
            ));
        }
        handle.shutdown();
        std::fs::remove_file(&ckpt).ok();
    }

    bench::write_json("serve_latency");
    Ok(())
}
