//! serve_bench — load generator for `repro serve` (DESIGN.md §Serving).
//!
//! Spawns an in-process server, fires concurrent generate traffic at it,
//! and reports client-side p50/p99 latency, throughput and server-side
//! batch occupancy; then repeats with batching disabled (max_batch 1) so
//! the batched-vs-sequential throughput ratio is read off directly —
//! the serving analogue of the paper's inference-efficiency claim.
//!
//!     cargo run --release --example serve_bench
//!
//! Env knobs: SERVE_BENCH_CLIENTS (8), SERVE_BENCH_REQS (25) per client,
//! SERVE_BENCH_CKPT (checkpoint path -> real PJRT engine; default mock
//! engine with a simulated 3 ms device cost so the harness runs
//! anywhere) and SERVE_BENCH_DOCS (tokenizer --docs match, 6000).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};
use spectron::serve::{MockEngine, ServeCfg, Server, ServerHandle};
use spectron::util::json::Json;
use spectron::util::stats::quantile;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn spawn_server(max_batch: usize) -> Result<ServerHandle> {
    let cfg = ServeCfg {
        addr: "127.0.0.1:0".into(),
        max_batch,
        max_wait: Duration::from_millis(10),
        workers: 1,
        default_variant: Some("mock".into()),
        metrics_name: None,
    };
    match std::env::var("SERVE_BENCH_CKPT") {
        Ok(ckpt) => {
            use spectron::runtime::ArtifactIndex;
            use spectron::serve::PjrtEngine;
            use spectron::train::checkpoint;
            let idx = ArtifactIndex::load(&ArtifactIndex::default_root())
                .map_err(|e| anyhow!("{e}\n  hint: run `make artifacts`"))?;
            let variant = checkpoint::peek_variant(std::path::Path::new(&ckpt))?;
            println!("engine: PJRT ({variant} from {ckpt})");
            let mut ckpts = std::collections::BTreeMap::new();
            ckpts.insert(variant.clone(), std::path::PathBuf::from(&ckpt));
            let mut cfg = cfg;
            cfg.default_variant = Some(variant);
            let docs = env_usize("SERVE_BENCH_DOCS", 6000) as u64;
            Server::spawn(cfg, PjrtEngine::factory(idx, ckpts, 2, docs))
        }
        Err(_) => {
            let seen = Arc::new(Mutex::new(Vec::new()));
            Server::spawn(cfg, MockEngine::factory(Duration::from_millis(3), seen))
        }
    }
}

/// One client worker: sequential request/response over its own
/// connection; concurrency comes from running many clients.
fn client(addr: std::net::SocketAddr, reqs: usize, cid: usize) -> Result<Vec<f64>> {
    let stream = TcpStream::connect(addr).context("connect")?;
    stream.set_read_timeout(Some(Duration::from_secs(60)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut lat_ms = Vec::with_capacity(reqs);
    for i in 0..reqs {
        let t0 = Instant::now();
        writeln!(
            writer,
            r#"{{"id":{i},"op":"generate","prompt":"client {cid} turn {i} of many","max_tokens":8,"temperature":0.7,"seed":{cid}}}"#
        )?;
        writer.flush()?;
        let mut line = String::new();
        anyhow::ensure!(reader.read_line(&mut line)? > 0, "server closed");
        let j = Json::parse(line.trim()).map_err(|e| anyhow!(e))?;
        anyhow::ensure!(
            j.get("ok") == Some(&Json::Bool(true)),
            "request failed: {line}"
        );
        lat_ms.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    Ok(lat_ms)
}

fn run_phase(name: &str, max_batch: usize, clients: usize, reqs: usize) -> Result<f64> {
    let handle = spawn_server(max_batch)?;
    let addr = handle.addr;
    let t0 = Instant::now();
    let lats: Vec<f64> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..clients)
            .map(|cid| scope.spawn(move || client(addr, reqs, cid)))
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("client thread").expect("client io"))
            .collect()
    });
    let wall = t0.elapsed().as_secs_f64();
    let stats = handle.shutdown();
    let total = (clients * reqs) as f64;
    let thr = total / wall;
    println!(
        "{name:<28} {total:>5.0} reqs in {wall:>6.2}s  {thr:>8.1} req/s   \
         p50 {:>7.2} ms  p99 {:>7.2} ms  occupancy {:>4.2}",
        quantile(&lats, 0.50),
        quantile(&lats, 0.99),
        stats.get("batch_occupancy_mean").and_then(|j| j.as_f64()).unwrap_or(0.0),
    );
    Ok(thr)
}

fn main() -> Result<()> {
    let clients = env_usize("SERVE_BENCH_CLIENTS", 8);
    let reqs = env_usize("SERVE_BENCH_REQS", 25);
    println!(
        "== serve_bench: {clients} concurrent clients x {reqs} generate requests ==\n"
    );

    let batched = run_phase("batched (max_batch=8)", 8, clients, reqs)?;
    let sequential = run_phase("sequential (max_batch=1)", 1, clients, reqs)?;

    let ratio = batched / sequential;
    println!("\nbatched / sequential throughput: {ratio:.2}x");
    if ratio <= 1.0 {
        println!("WARNING: batching did not win — check max_wait vs execute cost");
    }
    Ok(())
}
