//! Mini isoFLOP sweep (paper Figure 9/8 in miniature): trains the z0..z2
//! scaling family at two small compute budgets, fits the quadratics and
//! the power law, and prints the compute-optimal trend.
//!
//!     cargo run --release --example scaling_sweep
//!
//! (The full grid lives behind `repro exp fig9`; this example keeps the
//! budgets tiny so it finishes in a couple of minutes.)

use std::sync::Arc;

use anyhow::Result;
use spectron::config::RunCfg;
use spectron::coordinator::sched::{Job, Scheduler};
use spectron::exp::{plot, Ctx};
use spectron::scaling::{isoflop, powerlaw, RunPoint};
use spectron::util::json::Json;

const SIZES: [&str; 4] = [
    "fact-z0-spectron",
    "fact-z1-spectron",
    "fact-z2-spectron",
    "fact-z3-spectron",
];
const TOKENS_PER_STEP: f64 = 8.0 * 128.0;

fn main() -> Result<()> {
    let budgets = [4.0e10, 1.0e11];
    let ctx = Arc::new(Ctx::new(2500, false)?);

    let mut jobs = Vec::new();
    let mut meta = Vec::new();
    for &c in &budgets {
        for v in SIZES {
            let n = ctx.idx.manifest(v)?.n_params as f64;
            let steps = ((c / (6.0 * n)) / TOKENS_PER_STEP).round().max(8.0) as usize;
            meta.push((c, v, n, steps));
            let ctx = ctx.clone();
            jobs.push(Job::new(format!("C={c:.0e} {v}"), move |cx| {
                let rt = cx.runtime()?;
                let run = RunCfg {
                    total_steps: steps,
                    base_lr: 0.01,
                    weight_decay: 0.01,
                    warmup_frac: 0.05,
                    seed: 10,
                    read_interval: 50,
                };
                let (_res, state) = ctx.train_run(rt, v, run, None)?;
                Ok(Json::num(ctx.ppl(rt, v, &state)?.ln()))
            }));
        }
    }
    println!("running {} isoFLOP cells on 4 workers ...", jobs.len());
    let results = Scheduler::new(4).run(jobs);

    let mut pts = Vec::new();
    for ((c, _v, n, steps), (name, r)) in meta.iter().zip(&results) {
        let loss = r
            .as_ref()
            .map_err(|e| anyhow::anyhow!("{name}: {e}"))?
            .as_f64()
            .unwrap();
        println!("  {name:<28} loss {loss:.4}");
        pts.push(RunPoint {
            params: *n,
            tokens: *steps as f64 * TOKENS_PER_STEP,
            flops: *c,
            loss,
        });
    }

    let fits = isoflop::fit_all(&pts);
    let series: Vec<plot::Series> = fits
        .iter()
        .map(|f| {
            plot::Series::new(
                &format!("C={:.0e}", f.flops),
                f.points.iter().map(|p| (p.params, p.loss)).collect(),
            )
        })
        .collect();
    println!(
        "{}",
        plot::render_logx("mini isoFLOP sweep", "params", "val loss", &series)
    );
    for f in &fits {
        println!(
            "C = {:.1e}:  N_opt ≈ {:.0} params, D_opt ≈ {:.0} tokens, loss {:.3}",
            f.flops, f.n_opt, f.d_opt, f.loss_min
        );
    }
    if fits.len() >= 2 {
        let pl = powerlaw::fit(&fits);
        println!(
            "\npower law over {} budgets: N_opt ∝ C^{:.3}, D_opt ∝ C^{:.3}",
            fits.len(),
            pl.a_n,
            pl.b_d
        );
        println!("(paper, full grid: 0.479 / 0.521 — run `repro exp fig8` for the real fit)");
    }
    println!("scaling_sweep OK");
    Ok(())
}
