//! Quickstart: the end-to-end driver required by DESIGN.md — train a
//! factorized transformer with Spectron from random init on the synthetic
//! corpus, log the loss curve, checkpoint, evaluate perplexity and the
//! downstream suite, and demonstrate resume.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Env knobs: QUICKSTART_STEPS (default 200), QUICKSTART_VARIANT.

use std::sync::Arc;

use anyhow::Result;
use spectron::config::RunCfg;
use spectron::data::dataset::Split;
use spectron::exp::Ctx;
use spectron::runtime::Runtime;
use spectron::train::{checkpoint, MetricsLog, Trainer};

fn main() -> Result<()> {
    let steps: usize = std::env::var("QUICKSTART_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(200);
    let variant = std::env::var("QUICKSTART_VARIANT")
        .unwrap_or_else(|_| "fact-s-spectron".to_string());

    println!("== Spectron quickstart: {variant}, {steps} steps ==\n");
    let ctx = Arc::new(Ctx::new(4000, false)?);
    let rt = Runtime::shared()?;
    let v = ctx.reg.variant(&variant).map_err(anyhow::Error::msg)?;
    let m = ctx.idx.manifest(&variant)?;
    println!(
        "model: {} (d={}, L={}, vocab={}), {} trainable params, optimizer {}",
        m.variant, m.hidden, m.layers, m.vocab, m.n_params, m.optimizer
    );

    // ---- train ----------------------------------------------------------
    let run = RunCfg {
        total_steps: steps,
        base_lr: 0.01,
        weight_decay: 0.01,
        warmup_frac: 0.05,
        seed: 0,
        read_interval: 20,
    };
    let mut trainer = Trainer::new(&rt, &ctx.idx, v, run.clone())?;
    let mut batches = ctx.ds.batches(Split::Train, v.batch, run.seed);
    let mut metrics = MetricsLog::with_file("quickstart")?;
    let half = steps / 2;

    println!("\ntraining first {half} steps ...");
    let res1 = trainer.train_with(&mut batches, half, &mut metrics)?;
    print_curve(&res1.losses);

    // ---- checkpoint + resume (proving save/restore round-trips) ---------
    let ckpt = spectron::repo_path("results/quickstart.ckpt");
    checkpoint::save(&ckpt, &variant, &trainer.state_vec()?)?;
    println!("checkpointed at step {} -> {}", trainer.state().step(), ckpt.display());

    let (_, state) = checkpoint::load(&ckpt)?;
    let mut trainer = Trainer::from_state(&rt, &ctx.idx, v, run.clone(), state)?;
    println!("resumed; training {} more steps ...", steps - half);
    let res2 = trainer.train_with(&mut batches, steps - half, &mut metrics)?;
    print_curve(&res2.losses);
    println!(
        "\nwall: {:.1}s total ({:.0} ms/step), loss {:.3} -> {:.3}",
        res1.wall_s + res2.wall_s,
        1e3 * (res1.wall_s + res2.wall_s) / steps as f64,
        res1.losses.first().map(|l| l.1).unwrap_or(f32::NAN),
        res2.final_loss
    );

    // ---- evaluate --------------------------------------------------------
    let state = trainer.state_vec()?;
    let ppl = ctx.ppl(&rt, &variant, &state)?;
    println!("\nvalidation perplexity: {ppl:.2} (uniform would be {})", m.vocab);
    assert!(ppl < m.vocab as f64 / 2.0, "model learned nothing?");

    for t in ctx.downstream(&rt, &variant, &state)? {
        println!(
            "downstream {:<10} acc {:>5.1}%  (chance {:>4.0}%)",
            t.task,
            t.accuracy * 100.0,
            t.chance * 100.0
        );
    }

    // the spectral telemetry the paper's method is all about
    let tel = trainer.state().telemetry();
    println!(
        "\nspectral state at the end: ||W||₂={:.3} ||ΔW||₂={:.5} |Δy|rms={:.5} ρ={:.5}",
        tel[0], tel[1], tel[2], tel[5]
    );
    println!(
        "paper Eq. 11 bound: ||ΔW||₂ = {:.5} <= lr = {:.5}  [{}]",
        tel[1],
        trainer.state().lr(),
        if tel[1] <= 1.4 * trainer.state().lr() { "holds" } else { "VIOLATED" }
    );
    println!("\nquickstart OK");
    Ok(())
}

fn print_curve(losses: &[(usize, f32)]) {
    if losses.is_empty() {
        return;
    }
    for (s, l) in losses.iter().step_by((losses.len() / 10).max(1)) {
        println!("  step {s:>5}  loss {l:.4}");
    }
}
