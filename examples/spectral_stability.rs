//! The instability demonstration (paper Figures 2/3 in miniature):
//! train the same factorized model with naive AdamW, Muon, and Spectron,
//! reading the in-graph spectral telemetry every step, and print the
//! ||ΔW||₂ trajectories — AdamW's grows orders of magnitude above the
//! orthogonalized methods while Spectron stays under its lr bound.
//!
//!     cargo run --release --example spectral_stability

use std::sync::Arc;

use anyhow::Result;
use spectron::config::RunCfg;
use spectron::data::dataset::Split;
use spectron::exp::{plot, Ctx};
use spectron::runtime::Runtime;
use spectron::train::Trainer;

fn main() -> Result<()> {
    let steps: usize = std::env::var("SPECTRAL_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(120);
    let runs: [(&str, f64); 3] = [
        ("fact-s-adamw", 0.001),
        ("fact-s-muon", 0.01),
        ("fact-s-spectron", 0.01),
    ];

    let ctx = Arc::new(Ctx::new(3000, false)?);
    let rt = Runtime::shared()?;
    let mut dw_series = Vec::new();
    let mut dy_series = Vec::new();
    let mut bound_ok = true;

    for (variant, lr) in runs {
        let v = ctx.reg.variant(variant).map_err(anyhow::Error::msg)?;
        let run = RunCfg {
            total_steps: steps,
            base_lr: lr,
            weight_decay: 0.01,
            warmup_frac: 0.05,
            seed: 5,
            read_interval: 1, // telemetry every step
        };
        let mut trainer = Trainer::new(&rt, &ctx.idx, v, run.clone())?;
        let mut batches = ctx.ds.batches(Split::Train, v.batch, run.seed);
        println!("training {variant} at lr {lr} ({steps} steps, per-step telemetry)...");
        let res = trainer.train(&mut batches, steps)?;
        let dw: Vec<(f64, f64)> = res
            .records
            .iter()
            .map(|r| (r.step as f64, r.telemetry[1] as f64))
            .collect();
        let dy: Vec<(f64, f64)> = res
            .records
            .iter()
            .map(|r| (r.step as f64, r.telemetry[2] as f64))
            .collect();
        // spectron's core guarantee (paper Eq. 11): ||dW||_2 <= ~lr
        if variant == "fact-s-spectron" {
            for r in &res.records {
                if r.telemetry[1] as f64 > 1.5 * r.lr.max(1e-9) {
                    bound_ok = false;
                }
            }
        }
        let max_dw = dw.iter().map(|p| p.1).fold(0.0, f64::max);
        println!("  max ||ΔW||₂ over run: {max_dw:.5}  (lr {lr})");
        dw_series.push(plot::Series::new(variant, dw));
        dy_series.push(plot::Series::new(variant, dy));
    }

    println!(
        "{}",
        plot::render_opts(
            "||ΔW||₂ per step (log scale) — layer-2 attention out projection",
            "step", "||dW||2", &dw_series, 72, 18, false, true
        )
    );
    println!(
        "{}",
        plot::render_opts(
            "|Δy|rms per step (log scale)",
            "step", "|dy|rms", &dy_series, 72, 18, false, true
        )
    );
    println!(
        "spectron bound check (||ΔW||₂ ≤ 1.5·lr at every step): {}",
        if bound_ok { "HOLDS" } else { "VIOLATED" }
    );
    assert!(bound_ok, "Spectron spectral bound violated");
    println!("spectral_stability OK");
    Ok(())
}
